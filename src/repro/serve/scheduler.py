"""Request scheduler: continuous batching with decode-interleaved chunked
prefill over the engine's slot arena.

Default mode ("continuous"): the batch axis is a SLOT ARENA.  Each loop
iteration first spends at most ``ServeConfig.prefill_token_budget`` tokens
advancing the head-of-queue request's CHUNKED prefill (one fixed-width
compiled chunk HLO per ``engine.prefill_chunk_step``; a request whose
prompt outruns the budget simply resumes next iteration), admitting it into
a free slot the moment its prompt completes (one compiled splice,
``engine.admit``, traced slot index) — then runs ONE ragged decode step for
the whole arena.  Resident sequences therefore never stall behind an
arriving prompt for more than the configured budget (rounded down to whole
chunks, minimum one chunk): long-prompt admission work and decoding
interleave instead of head-of-line blocking.  A request submitted
mid-generation joins the running batch as soon as its chunks are paid for,
a finished request's slot is recycled immediately, and the jitted decode /
chunk / splice HLOs are each compiled once and reused across all
admissions — no recompiles, no cache compaction, no drain barrier.

PAGED mode (ISSUE 5, ``ServeConfig.page_size > 0``): the SALS segments'
backing store is a refcounted page pool (``core/pager.py``) instead of the
dense slot arena, and this scheduler is its MEMORY MANAGER:

  * admission is a PAGE RESERVATION — a request is admitted when the pool
    has pages for its prompt (suffix), not when a slot index frees up; on
    shortfall it stalls at the head of the queue (``admission_stalls``)
    until residents release pages, after LRU prefix-cache entries have
    been evicted;
  * prompts sharing a registered prefix map their leading page-table
    entries to the SAME physical pages (refcount bump, ``prefix_hits``)
    and resume their chunked prefill at the page boundary — N concurrent
    same-system-prompt requests cost one prefill and one stored copy of
    the prefix;
  * decode growth allocates one page per ``page_size`` generated tokens;
    a write landing on a still-shared page triggers copy-on-write
    (``cow_copies``) — structurally the cache is append-only and sharing
    is whole-page, so this is a guarded safety net, not a hot path;
  * pool exhaustion mid-decode evicts the resident that could not map its
    write page back onto the queue (``evictions``; greedy decoding makes
    the re-run deterministic).  SELF-eviction is the anti-livelock policy:
    survivors keep every page they own, so at least one resident always
    runs to completion between evictions — no steal-back ping-pong;
  * every decode step appends a gauge row to ``pool_gauges``
    (pages_in_use / pages_free / cumulative counters) — the capacity
    ledger tests and benchmarks read.

TWO-TIER mode (ISSUE 7, ``ServeConfig.hbm_pages > 0``): the pool is a
:class:`~repro.core.tiering.TieredPagePool` — live-page capacity stays
``pool_pages`` (host RAM) while the device payload pools hold only
``hbm_pages`` hot slots.  The scheduler adds, around the same decode loop:

  * WRITE PINS — each resident's current write page is pinned hot
    (``ensure_write_pin``; the per-token append lands in it through the
    hot table every step);
  * PREFETCH — before each step, every row's PREVIOUS selection is warmed
    host→HBM (``tier_prefetch``; the paper's step-to-step selection
    stability is the hit-rate model, measured by benchmarks/overlap_score);
  * FETCH-AND-RERUN — the decode step collects the selected-page mask; if
    any selected page was cold (its reconstruction read the trash slot),
    the scheduler fetches it hot and RERUNS the identical step — all cache
    writes are idempotent per-position ``.set``s, so the final all-hot run
    is bit-identical to an all-HBM step;
  * gauges ``host_pages`` / ``fetch_hits`` / ``prefetch_hits`` /
    ``cold_misses`` / ``spills`` ride on ``pool_gauges``, and
    ``audit_pager`` extends to tier conservation (hot ⊎ cold ⊎ fresh ⊎
    in-flight == live, slot conservation, pins hot-only);
  * THRASH SHEDDING — when one step's working set (touched pages + write
    pins) exceeds the hot tier (:class:`HotTierThrash`), the scheduler
    sheds LOAD, not the request: the demanding row self-evicts to the
    queue head (``shed_thrash``), dropping the multiprogramming degree so
    the survivors' working set fits — the classic thrash response.  Only
    a SOLE resident that thrashes (its own selection cannot fit hot) fails
    through the per-request retry budget.

FAULT TOLERANCE (ISSUE 6).  Every request carries the terminal state
machine of ``serve/lifecycle.py`` (QUEUED → PREFILLING → DECODING →
{DONE, FAILED, CANCELLED, TIMED_OUT}); all mutations go through
``lifecycle.transition``.  The guarantees:

  * ISOLATION — a fault in any per-request phase (prefill chunk, admission
    splice, page alloc / COW during upkeep, NaN/inf logits or out-of-vocab
    sample on one row) fails THAT request only; its pages, prefix pins and
    slot are released through one idempotent teardown and the remaining
    residents keep decoding.  The engine's injection points fire BEFORE
    each donating jitted call, so an injected fault never strands donated
    buffers — a real fault after donation is unrecoverable by design and
    propagates.
  * BOUNDED RETRY — faults marked ``transient`` requeue the request with
    exponential backoff in scheduler steps (``retry_backoff_steps · 2^i``
    capped at ``retry_backoff_cap_steps``) up to ``max_request_retries``;
    greedy decoding makes every re-run token-exact.  Batch-wide
    ``decode_step`` faults retry the step itself under the same bound.
  * DEADLINES & CANCELLATION — ``request_timeout_steps`` (per-request
    override on ``Request.timeout_steps``) and ``Request.cancel()`` both
    route through the same teardown at the next step boundary, whatever
    phase the request is in.
  * BACKPRESSURE — ``max_queue`` bounds the pending queue; ``submit``
    raises ``QueueFull`` ("reject") or cancels the oldest pending request
    ("shed-oldest").
  * AUDIT — ``audit_serving_state()`` proves page conservation across
    pool / page tables / prefix pins / gauges (``core.pager.audit_pager``)
    plus slot↔state coherence; it runs every ``audit_every`` steps and on
    every teardown when auditing is enabled.

SLO SCHEDULING (ISSUE 8).  The front door is no longer plain FIFO:

  * PRIORITY CLASSES — ``Request.priority`` ∈ [0, priority_classes);
    admission always serves the highest eligible class first, and with
    ``preempt_policy != "none"`` a strictly higher waiting class preempts
    the lowest-priority (most recently admitted) resident when no slot is
    free.  Under ``"park"`` the victim keeps its PAGES: the slot's window
    state snapshots to host (``engine.detach_slot``), the page table moves
    into a parked record (refcounts held — SALS's compressed latents make
    this cheap, the LoRC argument), and resume splices the snapshot back
    into any free slot and continues DECODING token-exact with no
    re-prefill.  Under tiering, parking drops the write pin and spills
    exclusively-parked pages cold, so the preemption actually frees hot
    slots.  ``"evict"`` is the destructive PR 5 baseline.  A page-stalled
    admission may reclaim a strictly-lower-priority parked victim's pages
    (destructive requeue) — parked sunk work never starves a higher class.
  * TENANT FAIRNESS — ``Request.tenant_id`` keys deficit-round-robin
    admission WITHIN a priority class (``tenant_quantum`` tokens per
    rotation turn; a request costs prompt + budget tokens), plus optional
    per-tenant token-rate credits (``tenant_rate``/step, debited at
    admission) and in-flight caps (``tenant_max_inflight``).
    ``tenant_gauges`` exports per-tenant starvation counters.
  * STREAMING — ``Request.on_token`` delivers each token the step it
    commits; mid-stream ``cancel()`` tears down at the next boundary and
    non-DONE teardowns flush the partial stream into a
    ``complete=False`` result.

SPECULATIVE DECODING (ISSUE 9, ``ServeConfig.spec_window > 1``): the
per-arena decode step becomes a VERIFY WINDOW — each resident's pending
token plus ``spec_window−1`` prompt-lookup drafts (``serve/draft.py``,
per-slot state on ``_Slot.drafter``) run through ONE compiled windowed
HLO (``engine._decode_window``: one latent selection amortized over the
window, one reconstruction pass attending every window query), greedy
verify accepts each row's longest matching draft prefix, and the masked
``engine._commit_window`` writes ONLY accepted positions — cache bytes
and the emitted token stream are bit-identical to sequential greedy
decode whatever the drafts were.  ``on_token`` fires once per ACCEPTED
token in commit order with contiguous indices; rejected draft positions
never reach the client.  Requires greedy decoding, an attention family
and the untiered cache (``config.base`` validates); paged rows map every
page the window span can touch before the step.  The ``draft_verify``
fault point fires before the windowed jit call, so an injected fault
retries the whole round like a ``decode_step`` fault.  Counters:
``spec_rounds`` / ``spec_proposed`` / ``spec_accepted`` /
``spec_committed``.

WALL-CLOCK DEADLINES (ISSUE 9): ``ServeConfig.request_timeout_ms`` (per-
request override ``Request.timeout_ms``) arms a monotonic-clock deadline
at submit, swept by the same teardown path as ``request_timeout_steps``
— either deadline may fire first.  The clock source is injectable
(``RequestScheduler(clock=...)``) for deterministic tests.

"static" mode survives as the GPT-fast-style baseline (and the fallback for
recurrent-state families, whose prefill can neither right-pad nor chunk):
fixed-size batches, length-bucketed FIFO (priority/tenant knobs are
continuous-mode only), monolithic prefill → decode-until-drained per batch.

Results are delivered on the ``Request`` objects in both modes; ``run``
returns every request that reached a terminal state during the call, in
completion order — check ``Request.state`` / ``Request.error`` to tell
DONE apart from FAILED / CANCELLED / TIMED_OUT.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pager import (PagePool, PageTable, PagerInvariantError,
                              PrefixIndex, audit_pager)
from repro.core.tiering import HotTierThrash, TieredPagePool
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs import traffic as obs_traffic
from repro.obs.metrics import MetricsRegistry
from repro.serve import faults
from repro.serve.draft import NgramDrafter
from repro.serve.engine import GenerationResult, PrefillTask, ServeEngine
from repro.serve.lifecycle import (NanLogitsError, QueueFull,
                                   RequestCancelled, RequestState,
                                   RequestTimeout, transition)

_req_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 32
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_ids))
    result: Optional[GenerationResult] = None
    # --- lifecycle (ISSUE 6) ------------------------------------------------
    state: RequestState = RequestState.QUEUED
    error: Optional[BaseException] = None
    timeout_steps: Optional[int] = None   # None = ServeConfig default
    timeout_ms: Optional[float] = None    # None = ServeConfig default
    retries: int = 0                      # transient-fault retries consumed
    deadline_step: Optional[int] = None   # set at submit
    deadline_time: Optional[float] = None  # wall-clock deadline (ISSUE 9)
    not_before_step: int = 0              # retry backoff gate
    cancel_requested: bool = False
    # --- SLO scheduling (ISSUE 8) ------------------------------------------
    priority: int = 0                     # class index; higher = more urgent
    tenant_id: str = "default"            # fairness / rate-limit key
    # Streaming: called as on_token(token_id, index) the step each token
    # commits (index 0 = the first token, emitted at admission).  Delivery
    # is at-least-once across destructive restarts (evict-to-requeue and
    # retry re-runs re-emit from index 0); a park/resume never re-emits.
    # A raising callback fails THIS request (non-transient).
    on_token: Optional[Callable[[int, int], None]] = None
    submit_step: Optional[int] = None     # set at submit (wait gauges)
    attempts: int = 0                     # times prefill started
    parks: int = 0                        # times preempt-parked

    def cancel(self) -> None:
        """Client cancellation: honored at the next scheduler step
        boundary via the same teardown path as faults and timeouts."""
        self.cancel_requested = True

    @property
    def done(self) -> bool:
        """Completed successfully (full budget generated)."""
        return self.state is RequestState.DONE

    @property
    def finished(self) -> bool:
        """Reached ANY terminal state (done / failed / cancelled / timed
        out) — the request owns no serving resources anymore."""
        return self.state.terminal


@dataclasses.dataclass
class _Slot:
    """One resident sequence of the continuous batch."""
    req: Request
    out: List[int]                 # generated token ids so far
    seq: int = 0                   # admission order (preemption tie-break)
    # speculative decoding (ISSUE 9): per-request prompt-lookup draft
    # state.  Rebuilt from prompt + out on every (re)admission and resume,
    # so evictions, retries and park/resume need no extra bookkeeping.
    drafter: Optional[NgramDrafter] = None


@dataclasses.dataclass
class _Admission:
    """Head-of-queue request being chunk-prefilled into a reserved slot.

    Paged mode: ``ptab`` holds the request's reserved page table (shared
    prefix pages + fresh suffix pages — the reservation IS the admission
    criterion) and ``shared_pages`` how many leading pages came from a
    prefix-cache entry (``entry``)."""
    req: Request
    slot: int
    task: PrefillTask
    ptab: Optional[PageTable] = None
    shared_pages: int = 0
    entry: object = None


@dataclasses.dataclass
class _Parked:
    """A preempt-parked resident (ISSUE 8): everything needed to resume
    DECODING token-exact in any free slot.  The record OWNS the request's
    page table — refcounts stay held across the park, which is the whole
    point: resume is a window splice, not a re-prefill."""
    req: Request
    out: List[int]                 # tokens committed before the park
    position: int                  # next decode position
    ptab: PageTable                # held pages (ownership moved from slot)
    snapshot: dict                 # engine.detach_slot host snapshot
    parked_step: int               # FIFO resume order within a class


class _CounterView:
    """ISSUE 10 migration shim: a legacy public int counter
    (``sched.prefix_hits`` et al.) that is now a THIN VIEW over the
    scheduler's :class:`~repro.obs.metrics.MetricsRegistry`.  Existing
    ``+= 1`` sites, tests and benchmarks keep working unchanged; the
    registry is the single store, so exporters can never disagree with
    the public fields."""

    __slots__ = ("metric",)

    def __init__(self, metric: str):
        self.metric = metric

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return int(obj.metrics.counter(self.metric).value())

    def __set__(self, obj, value):
        obj.metrics.counter(self.metric).set_to(float(value))


class RequestScheduler:
    """``mode``: "continuous" (default, from ``engine.scfg.scheduler``) or
    "static".  Recurrent-state families always run static (see engine).

    Observability hooks the scheduler tests assert against:
      ``admissions``     — (decode_step_index, slot, req_id) per admission
                           (join latency, slot recycling, FIFO);
      ``prefill_chunks`` — (decode_step_index, req_id, chunk_index,
                           n_resident) per chunk HLO executed (the
                           interleaving ledger: the number of entries
                           sharing a step index with n_resident > 0 bounds
                           how long residents waited between decode steps).
    """

    # Public counters, migrated onto the metrics registry (ISSUE 10).
    # Reads and ``+= 1`` writes behave exactly as the old plain ints did.
    prefix_hits = _CounterView("serve_prefix_hits_total")
    cow_copies = _CounterView("serve_cow_copies_total")
    admission_stalls = _CounterView("serve_admission_stalls_total")
    evictions = _CounterView("serve_evictions_total")
    failures = _CounterView("serve_requests_failed_total")
    timeouts = _CounterView("serve_requests_timed_out_total")
    cancellations = _CounterView("serve_requests_cancelled_total")
    retries = _CounterView("serve_retries_total")
    step_faults = _CounterView("serve_step_faults_total")
    shed = _CounterView("serve_shed_total")
    fetch_hits = _CounterView("serve_fetch_hits_total")
    prefetch_hits = _CounterView("serve_prefetch_hits_total")
    cold_misses = _CounterView("serve_cold_misses_total")
    spec_rounds = _CounterView("serve_spec_rounds_total")
    spec_proposed = _CounterView("serve_spec_proposed_total")
    spec_accepted = _CounterView("serve_spec_accepted_total")
    spec_committed = _CounterView("serve_spec_committed_total")
    parks = _CounterView("serve_parks_total")
    resumes = _CounterView("serve_resumes_total")
    preemptions = _CounterView("serve_preemptions_total")
    submitted = _CounterView("serve_requests_submitted_total")
    done = _CounterView("serve_requests_done_total")

    def __init__(self, engine: ServeEngine, max_batch: Optional[int] = None,
                 mode: Optional[str] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.engine = engine
        # wall-clock source for request_timeout_ms deadlines (ISSUE 9);
        # injectable so deadline tests are deterministic, monotonic so a
        # system clock step never expires (or revives) a request
        self._clock: Callable[[], float] = clock or time.monotonic
        self.max_batch = max_batch or engine.scfg.max_batch
        mode = mode or engine.scfg.scheduler
        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        if not engine.ragged_ok:
            mode = "static"        # recurrent state can't right-pad or chunk
        if engine.paged and mode != "continuous":
            raise ValueError("the paged latent cache requires the "
                             "continuous scheduler (admission = page "
                             "reservation)")
        self.mode = mode
        # deque, not list (ISSUE 8): admission pops the head and
        # evict-to-requeue pushes it — both O(1); list.pop(0) is O(n)
        # under deep queues
        self.pending: collections.deque = collections.deque()
        self.completed: Dict[int, Request] = {}
        # Observability ledgers become RING BUFFERS when
        # ServeConfig.gauge_history > 0 (ISSUE 8 bugfix: they otherwise
        # grow one row per step/chunk forever in long-running serving);
        # 0 = unbounded full history for the tests that read whole ledgers.
        hist = engine.scfg.gauge_history or None
        self.admissions: collections.deque = \
            collections.deque(maxlen=hist)      # (step, slot, req_id)
        # (step, req_id, chunk_idx, n_resident) — see class docstring
        self.prefill_chunks: collections.deque = collections.deque(
            maxlen=hist)
        self.steps: int = 0                     # decode steps executed
        # --- paged-pool observability (ISSUE 5 satellite) ------------------
        # one gauge row per decode step: the capacity ledger for tests +
        # benchmarks (pages_in_use ≈ prefix + Σ unique suffixes under
        # prefix sharing, high-water = peak live tokens, ...)
        self.pool_gauges: collections.deque = collections.deque(maxlen=hist)
        # --- unified telemetry (ISSUE 10) ----------------------------------
        # The registry is the single store behind every public counter
        # above the class (``_CounterView``): an externally installed
        # registry (``obs.metrics.install``) is adopted so exporters see
        # this scheduler; otherwise a private one backs the views at the
        # same cost.  Label-set growth shares the gauge_history cap.
        self.metrics: MetricsRegistry = (
            obs_metrics.active()
            or MetricsRegistry(max_series=hist or 0))
        # per-step gauge publishing only runs for an INSTALLED registry
        # (someone is scraping); the private fallback registry exists just
        # to back the counter views, so disabled mode stays one-check cheap
        self._metrics_installed = obs_metrics.active() is not None
        self.tracer = obs_trace.active()        # None = spans disabled
        self.traffic = obs_traffic.active()     # None = no byte accounting
        for view in (
                # paged-pool observability (ISSUE 5 satellite)
                "prefix_hits",       # admissions reusing pages
                "cow_copies",        # copy-on-write page dups
                "admission_stalls",  # sweeps blocked on pages
                "evictions",         # evict-to-requeue events
                # fault-tolerance observability (ISSUE 6)
                "failures",          # requests ending FAILED
                "timeouts",          # requests ending TIMED_OUT
                "cancellations",     # requests ending CANCELLED
                "retries",           # transient requeues granted
                "step_faults",       # batch-wide decode retries
                "shed",              # queue-policy sheds
                # two-tier pool observability (ISSUE 7)
                "fetch_hits",        # touched pages already hot
                "prefetch_hits",     # ... warmed by the prefetcher
                "cold_misses",       # demand host→HBM fetches
                # speculative decoding observability (ISSUE 9)
                "spec_rounds",       # verify windows executed
                "spec_proposed",     # draft tokens proposed
                "spec_accepted",     # draft tokens accepted
                "spec_committed",    # tokens committed via windows
                # SLO scheduling (ISSUE 8)
                "parks",             # preempt-park events
                "resumes",           # successful park resumes
                "preemptions",       # park + evict preemptions
                # request conservation (ISSUE 10): submitted must equal
                # done+failures+timeouts+cancellations at drain
                "submitted", "done"):
            setattr(self, view, 0)
        self.parked: List[_Parked] = []         # live parked records
        # per-tenant starvation/fairness gauges (see _tenant_gauge);
        # insertion-ordered so the gauge_history LRU cap can evict the
        # least-recently-touched tenant (ISSUE 10 bugfix)
        self.tenant_gauges: "collections.OrderedDict[str, dict]" = \
            collections.OrderedDict()
        self._drr_rot: Dict[int, List[str]] = {}      # DRR rotation / class
        self._drr_deficit: Dict[int, Dict[str, float]] = {}
        self._rate_credit: Dict[str, float] = {}      # tenant token credit
        self.paged = engine.paged and mode == "continuous"
        self.tiered = engine.tiered and mode == "continuous"
        self.pool: Optional[PagePool] = None
        self.prefix_index: Optional[PrefixIndex] = None
        if self.paged:
            scfg = engine.scfg
            # +1 / n_reserved=1: physical page 0 is the trash page
            if self.tiered:
                self.pool = TieredPagePool(scfg.pool_pages + 1,
                                           scfg.page_size,
                                           hbm_slots=scfg.hbm_pages,
                                           n_reserved=1)
            else:
                self.pool = PagePool(scfg.pool_pages + 1, scfg.page_size,
                                     n_reserved=1)
            if scfg.prefix_cache:
                self.prefix_index = PrefixIndex(self.pool)
            if self.traffic is not None:
                self.traffic.bind_page_size(scfg.page_size)
        # live loop state, mirrored on self so audit_serving_state can see
        # it mid-run (tests also call it after run: drained == empty)
        self._slots: List[Optional[_Slot]] = []
        self._tables: List[Optional[PageTable]] = []
        self._active: Optional[_Admission] = None

    def submit(self, req: Request) -> int:
        if req.max_new_tokens < 1:
            raise ValueError(f"req {req.req_id}: max_new_tokens must be >= 1 "
                             "(prefill always emits the first token)")
        if len(req.prompt) + req.max_new_tokens > self.engine.scfg.max_seq_len:
            # reject HERE, not mid-run: an oversized request must not abort
            # a running batch and strand its residents
            raise ValueError(
                f"req {req.req_id}: prompt {len(req.prompt)} + new "
                f"{req.max_new_tokens} exceeds max_seq "
                f"{self.engine.scfg.max_seq_len}")
        if self.paged:
            ps = self.engine.scfg.page_size
            need = -(-(len(req.prompt) + req.max_new_tokens) // ps)
            if need > self.engine.scfg.pool_pages:
                raise ValueError(
                    f"req {req.req_id}: needs {need} pages at its longest; "
                    f"the pool has {self.engine.scfg.pool_pages}")
        scfg = self.engine.scfg
        if not 0 <= req.priority < scfg.priority_classes:
            raise ValueError(
                f"req {req.req_id}: priority {req.priority} outside "
                f"[0, {scfg.priority_classes})")
        if scfg.max_queue and len(self.pending) >= scfg.max_queue:
            if scfg.queue_policy == "reject":
                raise QueueFull(
                    f"pending queue at max_queue={scfg.max_queue}")
            # shed-oldest: a pending request makes room — its submitter
            # sees state CANCELLED with a QueueFull error.  Victim choice
            # is _shed_victim_index, NOT blindly pending[0] (ISSUE 8
            # bugfix): already-doomed and never-started requests go first.
            idx = self._shed_victim_index()
            victim = self.pending[idx]
            del self.pending[idx]
            self._terminate(victim, RequestState.CANCELLED,
                            QueueFull("shed for newer request"))
            self.shed += 1
        timeout = (req.timeout_steps if req.timeout_steps is not None
                   else scfg.request_timeout_steps)
        if timeout:
            req.deadline_step = self.steps + timeout
        # wall-clock deadline (ISSUE 9): EITHER deadline may fire — both
        # sweep through the same TIMED_OUT teardown path
        timeout_ms = (req.timeout_ms if req.timeout_ms is not None
                      else scfg.request_timeout_ms)
        if timeout_ms:
            req.deadline_time = self._clock() + timeout_ms / 1000.0
        req.submit_step = self.steps
        self._tenant_gauge(req.tenant_id)["submitted"] += 1
        self.submitted += 1
        if self.tracer is not None:
            self.tracer.begin("queue_wait", f"req{req.req_id}",
                              tenant=req.tenant_id, priority=req.priority)
        self.pending.append(req)
        return req.req_id

    def _shed_victim_index(self) -> int:
        """shed-oldest victim policy (ISSUE 8 bugfix).  Preference order:
        1. the oldest CANCEL-REQUESTED pending request — it is already
           doomed to be swept CANCELLED, so shedding it costs nothing;
        2. the oldest NEVER-STARTED request (no prefill attempt, no retry
           budget consumed) — shedding it discards no work;
        3. the oldest outright.
        The old policy popped pending[0] blindly, which could discard a
        backoff-parked retried request's consumed retry work while a
        cancel-requested request behind it survived to be swept anyway."""
        for idx, r in enumerate(self.pending):
            if r.cancel_requested:
                return idx
        for idx, r in enumerate(self.pending):
            if r.attempts == 0 and r.retries == 0:
                return idx
        return 0

    # ----------------------------------------------------------- lifecycle

    def _trace_phase(self, req: Request, name: str, **args) -> None:
        """Move ``req`` to lifecycle phase ``name`` on its trace track
        (ISSUE 10).  Each request has AT MOST ONE open span — its current
        phase — so closing the previous phase first keeps begin/end
        balanced through every teardown, retry, eviction and park path.
        No-op when tracing is disabled."""
        tr = self.tracer
        if tr is not None:
            track = f"req{req.req_id}"
            tr.end_track(track)
            tr.begin(name, track, **args)

    def _terminate(self, req: Request, state: RequestState,
                   error: Optional[BaseException] = None,
                   issued: Optional[List[Request]] = None,
                   partial: Optional[tuple] = None) -> None:
        """Move ``req`` to a terminal state and record it.  The caller has
        already released every resource the request held.

        ``partial`` (ISSUE 8 streaming): ``(tokens_so_far, prompt_len)``
        from a request dying mid-decode — flushed into a
        ``complete=False`` result on any non-DONE terminal of a STREAMING
        request (``on_token`` set), so the client keeps what it was
        already delivered.  Non-streaming requests keep the pre-existing
        contract: a non-DONE terminal leaves ``result`` None.  Retries and
        evictions never flush (the request is not terminal; its re-run
        re-emits)."""
        transition(req, state, error)
        if state is RequestState.FAILED:
            self.failures += 1
        elif state is RequestState.TIMED_OUT:
            self.timeouts += 1
        elif state is RequestState.CANCELLED:
            self.cancellations += 1
        elif state is RequestState.DONE:
            self.done += 1
        if self.tracer is not None:
            # close whatever lifecycle phase was open (queue_wait /
            # prefill / decode / parked — teardown can arrive from ANY of
            # them) so spans balance on every terminal path, then record
            # the teardown itself
            track = f"req{req.req_id}"
            self.tracer.end_track(track)
            self.tracer.end(self.tracer.begin("teardown", track,
                                              state=state.name))
        if partial is not None and state is not RequestState.DONE \
                and req.on_token is not None \
                and req.result is None and partial[0]:
            toks, plen = partial
            req.result = GenerationResult(np.asarray(toks, np.int32), plen,
                                          len(toks), complete=False)
        self.completed[req.req_id] = req
        if issued is not None:
            issued.append(req)

    def _backoff(self, retries: int) -> int:
        scfg = self.engine.scfg
        return min(scfg.retry_backoff_steps * (2 ** max(0, retries - 1)),
                   scfg.retry_backoff_cap_steps)

    def _fail_or_retry(self, req: Request, exc: BaseException,
                       issued: List[Request],
                       partial: Optional[tuple] = None) -> None:
        """Supervisor policy for one faulted request (resources already
        released): transient faults requeue with exponential backoff in
        scheduler steps; anything else — or an exhausted retry budget —
        terminates the request as FAILED with the fault attached.

        Deadline interaction (ISSUE 8 bugfix): a retry whose backoff gate
        lands at or past the request's deadline could never run again — it
        would sit in pending only to be swept TIMED_OUT later with zero
        re-runs (and no retry budget consumed against a fault that already
        happened).  Policy: FAIL FAST — terminate TIMED_OUT at requeue
        time with the triggering fault chained as ``__cause__``.  The
        deadline is an SLO promise to the client; silently extending it by
        the backoff would lie about it."""
        scfg = self.engine.scfg
        if getattr(exc, "transient", False) \
                and req.retries < scfg.max_request_retries:
            gate = self.steps + self._backoff(req.retries + 1)
            if req.deadline_step is not None and gate >= req.deadline_step:
                err = RequestTimeout(
                    f"req {req.req_id}: retry backoff gate (step {gate}) "
                    f"cannot beat deadline step {req.deadline_step}")
                err.__cause__ = exc
                self._terminate(req, RequestState.TIMED_OUT, err, issued,
                                partial=partial)
                return
            req.retries += 1
            req.not_before_step = gate
            transition(req, RequestState.QUEUED)
            self.retries += 1
            self._trace_phase(req, "queue_wait", retry=req.retries)
            self.pending.append(req)
        else:
            self._terminate(req, RequestState.FAILED, exc, issued,
                            partial=partial)

    # ------------------------------------------- tenant fairness (ISSUE 8)

    @staticmethod
    def _cost(req: Request) -> int:
        """A request's admission cost in tokens: prompt + decode budget —
        what it will pin in pages/slot-time, known at submit."""
        return len(req.prompt) + req.max_new_tokens

    def _tenant_gauge(self, tenant: str) -> dict:
        """Per-tenant starvation/fairness counters (created on first
        touch): submissions, admissions (+tokens), deferrals by cause,
        and the worst admission wait seen, in steps.

        LRU-capped by ``gauge_history`` (ISSUE 10 bugfix; 0 = unbounded,
        the same ring policy as ``pool_gauges``): the old ``setdefault``
        dict grew one entry per unique tenant id FOREVER — a long-running
        front door with per-user tenant ids leaks without bound.  Every
        touch refreshes recency; past the cap the least-recently-touched
        tenant's gauges are dropped (it restarts from zero if it ever
        returns — starvation gauges are ring history, not billing)."""
        g = self.tenant_gauges.get(tenant)
        if g is None:
            g = {"submitted": 0, "admitted": 0, "admitted_tokens": 0,
                 "rate_deferrals": 0, "cap_deferrals": 0,
                 "max_wait_steps": 0}
            self.tenant_gauges[tenant] = g
        else:
            self.tenant_gauges.move_to_end(tenant)
        cap = self.engine.scfg.gauge_history
        while cap and len(self.tenant_gauges) > cap:
            self.tenant_gauges.popitem(last=False)
        return g

    def _tenant_inflight(self, tenant: str) -> int:
        """Requests of ``tenant`` currently holding serving resources:
        residents + parked + the in-flight admission."""
        n = sum(1 for s in self._slots
                if s is not None and s.req.tenant_id == tenant)
        n += sum(1 for rec in self.parked
                 if rec.req.tenant_id == tenant)
        if self._active is not None \
                and self._active.req.tenant_id == tenant:
            n += 1
        return n

    def _refill_rate_credits(self) -> None:
        """Accrue per-tenant admission credit (``tenant_rate`` tokens per
        scheduler iteration) while the tenant has pending work, capped at
        32 iterations' worth so an idle-then-bursty tenant cannot bank
        unbounded credit.  Admission debits the request cost — credit may
        go negative, PACING a burst instead of rejecting it."""
        rate = self.engine.scfg.tenant_rate
        if not rate:
            return
        for t in {r.tenant_id for r in self.pending}:
            self._rate_credit[t] = min(
                self._rate_credit.get(t, 0.0) + rate, rate * 32)

    def _eligible(self, r: Request, count: bool = False) -> bool:
        """Admission gates for one pending request: retry backoff elapsed,
        tenant in-flight cap, tenant rate credit.  ``count=True`` records
        deferrals in the tenant gauges (admission-sweep probes only, so
        the counters track real deferred admission attempts)."""
        if r.not_before_step > self.steps:
            return False
        scfg = self.engine.scfg
        if scfg.tenant_max_inflight and self._tenant_inflight(r.tenant_id) \
                >= scfg.tenant_max_inflight:
            if count:
                self._tenant_gauge(r.tenant_id)["cap_deferrals"] += 1
            return False
        if scfg.tenant_rate \
                and self._rate_credit.get(r.tenant_id, 0.0) <= 0.0:
            if count:
                self._tenant_gauge(r.tenant_id)["rate_deferrals"] += 1
            return False
        return True

    def _note_admission(self, req: Request) -> None:
        """Fairness bookkeeping for a popped (about-to-admit) request:
        rate-credit debit + tenant gauges."""
        g = self._tenant_gauge(req.tenant_id)
        g["admitted"] += 1
        g["admitted_tokens"] += self._cost(req)
        if req.submit_step is not None:
            g["max_wait_steps"] = max(g["max_wait_steps"],
                                      self.steps - req.submit_step)
        if self.engine.scfg.tenant_rate:
            self._rate_credit[req.tenant_id] = \
                self._rate_credit.get(req.tenant_id, 0.0) - self._cost(req)

    def _drr_pick(self, prio: int, heads: Dict[str, int]) -> int:
        """Deficit round robin within priority class ``prio``.  ``heads``
        maps tenant -> pending index of that tenant's FIFO head.  Each
        rotation turn banks ``tenant_quantum`` tokens of deficit for the
        tenant at the rotation head; the first tenant whose head request
        costs <= its deficit is served and debited.  Tenants rotate in
        first-seen order; a tenant with no eligible work loses its bank
        (classic DRR — credit does not survive idleness).  Returns the
        chosen pending index."""
        rot = self._drr_rot.setdefault(prio, [])
        for t in heads:
            if t not in rot:
                rot.append(t)
        defc = self._drr_deficit.setdefault(prio, {})
        q = self.engine.scfg.tenant_quantum
        costs = {t: self._cost(self.pending[i]) for t, i in heads.items()}
        # enough turns that the costliest head MUST accumulate its cost
        turns = len(rot) * (max(costs.values()) // q + 2)
        for _ in range(turns):
            t = rot.pop(0)
            rot.append(t)
            if t not in heads:
                defc[t] = 0.0
                continue
            defc[t] = defc.get(t, 0.0) + q
            if costs[t] <= defc[t]:
                defc[t] -= costs[t]
                return heads[t]
        return min(heads.values())    # unreachable bound: FIFO head

    # ------------------------------------------------------------------ run

    def run(self, on_batch: Optional[Callable[[List[Request]], None]] = None,
            on_step: Optional[Callable[["RequestScheduler", int], None]] = None
            ) -> List[Request]:
        """Drain the queue; returns terminal requests in completion order.

        ``on_step`` (continuous mode) fires after every decode step — tests
        and clients use it to submit requests mid-generation; their prefill
        chunks start within the very next iteration's budget.  ``on_batch``
        (static mode) fires after each drained batch.
        """
        if self.mode == "static":
            return self._run_static(on_batch)
        return self._run_continuous(on_step)

    # ------------------------------------------------------------ continuous

    def _run_continuous(self, on_step) -> List[Request]:
        eng = self.engine
        if self.max_batch != eng.scfg.max_batch:
            raise ValueError("continuous mode uses the engine's slot arena: "
                             f"max_batch {self.max_batch} != "
                             f"engine {eng.scfg.max_batch}")
        b = self.max_batch
        chunk = eng.scfg.prefill_chunk
        ps = eng.scfg.page_size
        mp = eng.scfg.max_seq_len // ps if self.paged else 0
        chunks_per_sweep = max(1, eng.scfg.prefill_token_budget // chunk)
        audit_on = bool(eng.scfg.audit_every)
        cache = eng.init_slot_cache()
        slots: List[Optional[_Slot]] = [None] * b
        self._slots = slots
        self._active = None        # in-flight admission; its slot reserved
        tokens = np.zeros((b,), np.int32)
        positions = np.zeros((b,), np.int32)
        key = jax.random.PRNGKey(eng.scfg.seed)
        issued: List[Request] = []
        admit_seq = itertools.count()   # admission order (preempt tie-break)
        prio_on = (eng.scfg.priority_classes > 1
                   and eng.scfg.preempt_policy != "none")
        # paged state: per-slot page tables + the host mirror of the device
        # table (pushed when dirty — decode writes need the page mapped)
        tables: List[Optional[PageTable]] = [None] * b
        self._tables = tables
        host_table = np.zeros((b, mp), np.int32) if self.paged else None
        dirty = [False]
        fault_streak = 0           # consecutive batch-wide decode faults
        # speculative decoding (ISSUE 9): width of the verify window the
        # decode step runs through the windowed kernels; 0/1 = sequential
        spec_q = eng.scfg.spec_window if eng.scfg.spec_window > 1 else 0
        # tiered state (ISSUE 7): the host mirror of the device hot-slot
        # table, each row's pinned-hot write page, and each row's previous
        # selection (the prefetch oracle)
        pool = self.pool
        host_hot = np.zeros((b, mp), np.int32) if self.tiered else None
        hot_dirty = [False]
        write_pin: List[Optional[int]] = [None] * b
        prev_selected: List[set] = [set() for _ in range(b)]

        def release_pages(i: int):
            nonlocal cache
            if not self.paged:
                return
            if self.tiered:
                # unpin BEFORE release_all: freeing a pinned page is an
                # invariant violation by design (catches leaked pins)
                if write_pin[i] is not None:
                    pool.unpin(write_pin[i])
                    write_pin[i] = None
                prev_selected[i] = set()
                host_hot[i] = 0
                hot_dirty[0] = True
            if tables[i] is not None:
                tables[i].release_all()
                tables[i] = None
            host_table[i] = 0
            dirty[0] = True
            cache = eng.release_slot(cache, i)   # metadata-only (lengths/pt)

        def clear_slot(i: int):
            """The idempotent slot teardown every exit path shares: frees
            the row (parked at position 0 — paged: the trash page — so its
            idle writes stay harmless), its pages, and its table entry.
            Request-state bookkeeping is the CALLER's job."""
            slots[i] = None        # recycled on the next admission sweep
            tokens[i] = 0
            positions[i] = 0
            release_pages(i)
            if audit_on:
                self.audit_serving_state()

        def teardown_admission(adm: _Admission):
            """Release an in-flight admission's reservation (pages incl.
            shared-prefix refcounts).  Idempotent: a torn reservation has
            ptab=None already, release_all on an empty table is a no-op,
            and no prefix entry exists yet (registration happens strictly
            after a successful splice), so nothing can leak a pin."""
            if adm.ptab is not None:
                adm.ptab.release_all()
                adm.ptab = None
            if audit_on:
                self.audit_serving_state()

        def finish(i: int):
            slot = slots[i]
            slot.req.result = GenerationResult(
                np.asarray(slot.out, np.int32), len(slot.req.prompt),
                len(slot.out))
            clear_slot(i)
            self._terminate(slot.req, RequestState.DONE, issued=issued)

        def fail_resident(i: int, exc: BaseException):
            """Per-request fault isolation: row ``i`` alone pays for its
            fault — teardown, then retry-or-fail; every other resident
            keeps decoding untouched.  Tokens committed before the fault
            ride along as the partial-stream flush (used only if the
            request terminates)."""
            req = slots[i].req
            out = list(slots[i].out)
            clear_slot(i)
            self._fail_or_retry(req, exc, issued,
                                partial=(out, len(req.prompt)))

        def drop_entries(n_needed: int, protect_entry=None) -> bool:
            """Evict least-recently-USED prefix-cache entries until
            >= n_needed pages are free (``protect_entry`` shields the
            entry an in-flight reservation is about to share — and a hot
            system-prompt entry naturally outlives one-shot prefixes).
            Entries are pure caches — always droppable, never
            correctness-bearing."""
            while self.pool.pages_free < n_needed and self.prefix_index:
                victim_e = self.prefix_index.lru_entry(exclude=protect_entry)
                if victim_e is None:
                    break
                self.prefix_index.evict(victim_e)
            return self.pool.pages_free >= n_needed

        def evict_to_requeue(i: int):
            """Pool exhausted and row ``i`` cannot map its next write page:
            evict THE ROW ITSELF back onto the queue head (releasing its
            pages) and let it restart later — greedy decoding makes the
            re-run produce identical tokens.  Self-eviction is what makes
            exhaustion livelock-free: the surviving residents keep every
            page they own, so at least one request always runs to
            completion between evictions (monotonic progress, no
            steal-back ping-pong)."""
            if eng.scfg.temperature > 0.0:
                # sampled decoding: the restart draws from an advanced key
                # stream, so the regenerated completion WILL differ — size
                # the pool for the workload (or run greedy) if that matters
                warnings.warn(
                    "paged pool exhausted: evicting a resident under "
                    "temperature > 0 — its re-run resamples and may "
                    "produce different tokens", RuntimeWarning,
                    stacklevel=2)
            req = slots[i].req
            clear_slot(i)
            transition(req, RequestState.QUEUED)   # eviction != a retry:
            req.not_before_step = 0                # no fault, no backoff
            self._trace_phase(req, "queue_wait", evicted=True)
            self.pending.appendleft(req)           # restarts from scratch
            self.evictions += 1

        def pop_eligible() -> Optional[Request]:
            """Pop the next request to admit: the highest eligible
            PRIORITY class first; within the class, deficit-round-robin
            across tenants (FIFO within one tenant, so plain FIFO falls
            out when every request shares a class and tenant — the
            pre-ISSUE-8 behavior).  Eligibility = retry backoff elapsed +
            tenant rate credit + tenant in-flight cap (_eligible)."""
            heads: Dict[str, int] = {}
            prio: Optional[int] = None
            for idx, r in enumerate(self.pending):
                if not self._eligible(r, count=True):
                    continue
                if prio is None or r.priority > prio:
                    prio, heads = r.priority, {}
                if r.priority == prio and r.tenant_id not in heads:
                    heads[r.tenant_id] = idx
            if prio is None:
                return None
            idx = next(iter(heads.values())) if len(heads) == 1 \
                else self._drr_pick(prio, heads)
            req = self.pending[idx]
            del self.pending[idx]
            self._note_admission(req)
            return req

        def try_reserve(req: Request) -> Optional[_Admission]:
            """Paged admission = page reservation: shared prefix pages +
            fresh suffix pages, or None (stall) if the pool can't cover
            the suffix right now.  The caller has POPPED ``req`` already —
            eviction-to-requeue inserts victims at the queue head, so the
            request being reserved must not still occupy that position.
            A fault mid-reservation (page_alloc, prefix_resume) releases
            the partial table before propagating — reservation is
            all-or-nothing."""
            prompt = np.asarray(req.prompt, np.int32)
            plen = len(prompt)
            entry, shared = (None, 0)
            if self.prefix_index is not None:
                entry, shared = self.prefix_index.match(prompt)
                # always leave >= 1 suffix token (the resumed chunk loop
                # must produce the prompt's next-token logits itself), and
                # never deeper than the boundary-ring snapshot cap
                shared = min(shared, (plen - 1) // ps,
                             self.engine.scfg.prefix_share_pages)
            n_new = -(-plen // ps) - shared
            if self.pool.pages_free < n_new and \
                    not drop_entries(n_new, protect_entry=entry):
                if entry is not None:
                    # sharing is an optimization, never an obligation: if
                    # protecting the matched entry is what starves the
                    # reservation, retry UNSHARED so that entry becomes
                    # evictable too — otherwise an entry pinning the pool
                    # with no residents left would stall admission forever
                    entry, shared = None, 0
                    n_new = -(-plen // ps)
                if self.pool.pages_free < n_new and not drop_entries(n_new):
                    # a new request never steals pages from running
                    # residents: it stalls at the queue head until they
                    # release pages
                    self.admission_stalls += 1
                    return None
            free = next(i for i in range(b) if slots[i] is None)
            ptab = PageTable(self.pool, mp)
            try:
                for j in range(shared):
                    ptab.append_shared(entry.page_ids[j])
                for _ in range(n_new):
                    ptab.append_page()
                if shared:
                    task = eng.start_prefill(prompt, resume=(entry, shared))
                else:
                    task = eng.start_prefill(prompt)
            except BaseException:
                ptab.release_all()         # all-or-nothing reservation
                raise
            if shared:
                self.prefix_hits += 1
                self.prefix_index.touch(entry)
            return _Admission(req, free, task, ptab=ptab,
                              shared_pages=shared, entry=entry)

        # ---- two-tier helpers (ISSUE 7) -----------------------------------

        def shed_thrash(i: int, exc: "HotTierThrash"):
            """Hot-tier thrash on row ``i``: shed load, not the request.
            With other residents live, evict row i to the queue head —
            its pins and hot pages free immediately, the survivors'
            working set shrinks, and the evicted request restarts later
            at a lower multiprogramming degree (greedy decode keeps the
            re-run token-identical).  A SOLE thrashing resident is a hard
            capacity misfit — its own per-step selection cannot fit the
            hot tier — and self-eviction would livelock, so that one goes
            through the transient retry budget and fails with the thrash
            attached."""
            if sum(s is not None for s in slots) > 1:
                evict_to_requeue(i)
            else:
                fail_resident(i, exc)

        def claim_slot(exclude) -> int:
            """A free hot payload slot, spilling the LRU unpinned hot page
            if none is free.  ``exclude``: pids that must stay hot (about
            to be read/written this step).  Raises HotTierThrash when
            every hot page is pinned or excluded (transient, per-row)."""
            nonlocal cache
            slot = pool.take_slot()
            if slot is None:
                victim = pool.spill_victim(exclude)
                if victim is None:
                    raise HotTierThrash(
                        f"no spillable hot page among {len(pool.hot)} "
                        f"({len(pool.pins)} pinned)")
                vslot = pool.begin_spill(victim)   # fires "spill" first
                sid = None if self.tracer is None else self.tracer.begin(
                    "tier_spill", "scheduler", page=victim)
                mirror = eng.read_page_payload(cache, vslot)
                pool.finish_spill(victim, mirror)
                self._note_transfer("spill", mirror, sid)
                hot_dirty[0] = True
                slot = pool.take_slot()
            return slot

        def fetch_page(pid: int, exclude) -> None:
            """Host→HBM demand/prefetch fetch of cold page ``pid``.  The
            fault points fire before any state change or transfer, so an
            injected host_fetch/spill fault leaves both tiers intact — the
            caller fails only the row that demanded the page."""
            nonlocal cache
            slot = claim_slot(exclude)
            try:
                payload = pool.begin_fetch(pid)    # fires "host_fetch" first
            except BaseException:
                pool.give_slot(slot)
                raise
            sid = None if self.tracer is None else self.tracer.begin(
                "tier_fetch", "scheduler", page=pid)
            try:
                cache = eng.load_page(cache, slot, payload)
            except BaseException:
                if sid is not None:
                    self.tracer.end(sid, aborted=True)
                pool.abort_fetch(pid)
                pool.give_slot(slot)
                raise
            pool.finish_fetch(pid, slot)
            self._note_transfer("fetch", payload, sid)
            hot_dirty[0] = True

        def ensure_write_pin(i: int):
            """Pin row i's current write page hot: the per-token decode
            write lands in it through the hot table every step, so it must
            hold a device slot for as long as writes target it.  Growth
            pages become hot IMMEDIATELY with garbage payload — per-row
            position masks keep unwritten rows unselectable, exactly the
            PR 5 recycled-page story."""
            nonlocal cache
            ptab = tables[i]
            pid = ptab.pages[int(positions[i]) // ps]
            if write_pin[i] == pid:
                return
            if write_pin[i] is not None:
                pool.unpin(write_pin[i])
                write_pin[i] = None
            if pid in pool.fresh:          # growth page: slot, no transfer
                pool.set_hot(pid, claim_slot({pid}))
            elif pid in pool.cold:         # write into a spilled page
                fetch_page(pid, {pid})
                self.cold_misses += 1
            pool.pin(pid)
            write_pin[i] = pid
            hot_dirty[0] = True

        def push_tables():
            """Push the host page table — and, tiered, the hot-slot table
            rebuilt from the pool's residency — to the device cache in one
            leaf swap."""
            nonlocal cache
            if self.tiered and (dirty[0] or hot_dirty[0]):
                slot_of = np.zeros((pool.n_pages,), np.int32)
                for pid, s in pool.hot.items():
                    slot_of[pid] = s
                host_hot[:] = slot_of[host_table]
                cache = eng.with_page_tables(cache, host_table, host_hot)
                dirty[0] = hot_dirty[0] = False
            elif dirty[0]:
                cache = eng.with_page_tables(cache, host_table)
                dirty[0] = False

        def assign_residency(adm: _Admission) -> List[int]:
            """First residency for an admission's FRESH pages: hot while
            free slots last, overflow cold (mirror extracted from the
            task's dense cache — those pages never touch the device pools).
            Admission never spills residents.  Returns the hot-slot row
            aligned to the reservation (shared pages keep the residency
            their registrant gave them)."""
            hot_row = []
            for j, pid in enumerate(adm.ptab.pages):
                if pid in pool.fresh:
                    slot = pool.take_slot()
                    if slot is not None:
                        pool.set_hot(pid, slot)
                    else:
                        pool.set_cold(pid, eng.extract_page_payload_dense(
                            adm.task.cache, j))
                hot_row.append(pool.hot.get(pid, 0))
            hot_dirty[0] = True
            return hot_row

        def tiered_decode(prefetched: set):
            """The tiered decode step: FETCH-AND-RERUN.  Run the selection-
            collecting decode; if any selected page was cold, its
            reconstruction read the trash slot — fetch the cold pages hot
            and rerun the SAME step on the returned cache.  Every cache
            write is an idempotent ``.set`` at a deterministic position, so
            the final run (all touched pages hot) is bit-identical to an
            all-HBM step.  Converges because the score pool is always
            true: run N's selection at the first miss-affected layer is
            final, so each round fixes at least one more layer — bounded
            by the layer count.  Returns the final logits, or None if
            every resident was torn down by injected fetch faults."""
            nonlocal cache
            rounds = 0
            while True:
                logits, cache, touched = eng._decode_sel(
                    jnp.asarray(tokens), cache, jnp.asarray(positions))
                tnp = np.asarray(touched)
                touched_all: set = set()
                new_prev: Dict[int, set] = {}
                demand: List[tuple] = []
                for i in range(b):
                    if slots[i] is None:
                        continue
                    pids = {int(host_table[i, j])
                            for j in np.nonzero(tnp[i])[0]}
                    pids.discard(0)
                    new_prev[i] = pids
                    touched_all |= pids
                    for pid in sorted(pids):
                        if pid in pool.cold:
                            demand.append((i, pid))
                pool.touch(p for p in sorted(touched_all) if p in pool.hot)
                if rounds == 0:
                    self.fetch_hits += sum(
                        1 for p in touched_all if p in pool.hot)
                    self.prefetch_hits += len(touched_all & prefetched)
                if not demand:
                    for i, pids in new_prev.items():
                        prev_selected[i] = pids
                    return logits
                rounds += 1
                if rounds > eng.cfg.n_layers + 2:
                    raise PagerInvariantError(
                        "tiered fetch-and-rerun did not converge in "
                        f"{rounds} rounds (selection unstable?)")
                for i, pid in demand:
                    if slots[i] is None:       # row died earlier this pass
                        continue
                    if pid not in pool.cold:   # fetched for an earlier row
                        continue
                    try:
                        fetch_page(pid, touched_all)
                        self.cold_misses += 1
                    except faults.InjectedFault as exc:
                        fail_resident(i, exc)
                    except HotTierThrash as exc:
                        shed_thrash(i, exc)
                if not any(s is not None for s in slots):
                    return None
                push_tables()

        def ensure_writable(i: int, span: int = 1):
            """Pre-decode page upkeep for resident row i: map every page
            its next ``span`` writes can land in (allocating on page
            crossings; a speculative verify window commits up to
            spec_window tokens in one step, so its span covers the whole
            window) and COW any still-shared target (structurally
            unreachable — sharing is whole-page and the cache append-only —
            but guarded so a future sharing policy cannot silently corrupt
            a shared page).  If the pool is exhausted even after dropping
            cache entries, the row evicts ITSELF to the queue (see
            evict_to_requeue).  Tiered: also pins the write page hot
            (ensure_write_pin)."""
            nonlocal cache
            ptab = tables[i]
            lo = int(positions[i]) // ps
            hi = (int(positions[i]) + span - 1) // ps
            for p in range(lo, hi + 1):
                if p >= ptab.n_pages:
                    if self.pool.pages_free < 1 and not drop_entries(1):
                        evict_to_requeue(i)
                        return
                    ptab.ensure_for_position(p * ps)
                    host_table[i, :ptab.n_pages] = ptab.pages
                    dirty[0] = True
                elif self.pool.refcount(ptab.pages[p]) > 1:
                    if self.pool.pages_free < 1 and not drop_entries(1):
                        evict_to_requeue(i)
                        return
                    old, new = ptab.ensure_exclusive(p)
                    if self.tiered:
                        # score page: physical-id copy, device-resident
                        cache = eng.copy_score_page(cache, old, new)
                        if old in pool.hot:
                            slot = claim_slot({old, new})
                            cache = eng.copy_page(cache, pool.hot[old], slot)
                            pool.set_hot(new, slot)
                        else:          # cold source: host-mirror duplicate
                            faults.maybe_fault("cow_copy")
                            pool.set_cold(new, {
                                seg: {f: v.copy() for f, v in fl.items()}
                                for seg, fl in pool.cold[old].items()})
                        hot_dirty[0] = True
                    else:
                        cache = eng.copy_page(cache, old, new)
                    host_table[i, p] = new
                    dirty[0] = True
                    self.cow_copies += 1
            if self.tiered:
                ensure_write_pin(i)

        def emit_tokens(i: int, n_new: int) -> bool:
            """Stream row ``i``'s ``n_new`` newest committed tokens through
            its request's ``on_token`` callback (ISSUE 8), in COMMIT ORDER
            with contiguous indices.  ISSUE 9 bugfix: a verify window that
            accepts k > 1 tokens fires the callback k times — once per
            accepted token, never for rejected draft positions — so the
            index sequence a client observes is exactly 0, 1, 2, ...
            whatever mix of sequential and speculative steps committed
            them.  A raising callback is the client's failure signal: it
            fails (non-transiently, unless the raised error says
            otherwise) THAT request alone — tokens already delivered stay
            delivered.  Returns False when the row was torn down."""
            req = slots[i].req
            if req.on_token is None:
                return True
            base = len(slots[i].out) - n_new
            for k in range(n_new):
                try:
                    req.on_token(int(slots[i].out[base + k]), base + k)
                except Exception as exc:
                    fail_resident(i, exc)
                    return False
            return True

        # ---- preempt-park machinery (ISSUE 8) -----------------------------

        def spill_parked_cold():
            """Hot-tier liveness half of a park: spill every page whose
            ONLY owners are parked tables (hot, unpinned, refcount ==
            parked multiplicity) to the host mirror, so the preemption
            actually frees device slots.  Pages shared with a live
            resident, an in-flight admission or a prefix entry keep their
            residency.  Runs after each park AND once per loop iteration:
            an injected ``spill`` fault just leaves the page hot until the
            next sweep retries (the tier auditor only enforces the safety
            rules — never pinned, never fresh)."""
            nonlocal cache
            if not (self.tiered and self.parked):
                return
            counts = collections.Counter()
            for rec in self.parked:
                counts.update(rec.ptab.pages)
            for pid, n in sorted(counts.items()):
                if pid in pool.hot and not pool.pins.get(pid) \
                        and pool.refcount(pid) == n:
                    try:
                        vslot = pool.begin_spill(pid)  # fires "spill" first
                    except faults.InjectedFault:
                        return         # retried next iteration
                    sid = None if self.tracer is None else self.tracer.begin(
                        "tier_spill", "scheduler", page=pid, parked=True)
                    mirror = eng.read_page_payload(cache, vslot)
                    pool.finish_spill(pid, mirror)
                    self._note_transfer("spill", mirror, sid)
                    hot_dirty[0] = True

        def park_resident(i: int):
            """Preempt-PARK resident row ``i``: snapshot its per-slot
            window state to host (engine.detach_slot — fires the ``park``
            fault point before any read, so an injected fault leaves the
            victim resident), move page-table ownership into a parked
            record WITHOUT releasing any page, free the batch slot.  Under
            tiering the write pin drops and exclusively-parked pages spill
            cold (see spill_parked_cold)."""
            nonlocal cache
            req = slots[i].req
            snap = eng.detach_slot(cache, i)
            rec = _Parked(req=req, out=slots[i].out,
                          position=int(positions[i]), ptab=tables[i],
                          snapshot=snap, parked_step=self.steps)
            slots[i] = None
            tokens[i] = 0
            positions[i] = 0
            tables[i] = None        # ownership moved to rec — NOT released
            host_table[i] = 0
            dirty[0] = True
            if self.tiered:
                if write_pin[i] is not None:
                    pool.unpin(write_pin[i])
                    write_pin[i] = None
                prev_selected[i] = set()
                host_hot[i] = 0
                hot_dirty[0] = True
            cache = eng.release_slot(cache, i)     # metadata-only
            transition(req, RequestState.PARKED)
            self._trace_phase(req, "parked")
            self.parked.append(rec)
            req.parks += 1
            self.parks += 1
            spill_parked_cold()
            if audit_on:
                self.audit_serving_state()

        def resume_parked(rec: _Parked, i: int) -> bool:
            """Resume a parked record into free slot ``i``: splice the
            window snapshot back (engine.attach_slot — fires the
            ``resume`` fault point before the donating call), reinstall
            the table row, continue DECODING token-exact.  On a resume
            fault the snapshot is still whole but the park is abandoned:
            held pages release and the request restarts from scratch
            through the standard retry policy (PARKED -> QUEUED/FAILED)."""
            nonlocal cache
            try:
                cache = eng.attach_slot(cache, i, rec.snapshot)
            except Exception as exc:
                rec.ptab.release_all()
                self._fail_or_retry(rec.req, exc, issued,
                                    partial=(rec.out, len(rec.req.prompt)))
                if audit_on:
                    self.audit_serving_state()
                return False
            tables[i] = rec.ptab
            host_table[i] = 0
            host_table[i, :rec.ptab.n_pages] = rec.ptab.pages
            dirty[0] = True
            if self.tiered:
                hot_dirty[0] = True  # hot rows rebuild from pool residency
            slots[i] = _Slot(rec.req, out=rec.out, seq=next(admit_seq),
                             drafter=NgramDrafter(
                                 list(rec.req.prompt) + rec.out)
                             if spec_q else None)
            tokens[i] = rec.out[-1]
            positions[i] = rec.position
            transition(rec.req, RequestState.DECODING)
            self._trace_phase(rec.req, "decode", resumed=True)
            self.resumes += 1
            if audit_on:
                self.audit_serving_state()
            return True

        def best_incoming_priority() -> Optional[int]:
            """Highest priority among eligible pending + parked requests —
            what a resident must be strictly below to be preempted."""
            best = None
            for r in self.pending:
                if self._eligible(r) and (best is None or r.priority > best):
                    best = r.priority
            for rec in self.parked:
                if best is None or rec.req.priority > best:
                    best = rec.req.priority
            return best

        def preempt_for_priority():
            """Park (or evict, per preempt_policy) the lowest-priority,
            most-recently-admitted resident while a strictly higher
            eligible class is waiting and no slot is free.  Never preempts
            below the incoming class (no same-priority churn) and never
            while an admission is in flight (it owns the next free slot;
            inversion is bounded by one prompt's chunks)."""
            if self._active is not None:
                return
            while True:
                if any(s is None for s in slots):
                    return
                best = best_incoming_priority()
                if best is None:
                    return
                vict = None
                for i in range(b):
                    s = slots[i]
                    if s is None or s.req.priority >= best:
                        continue
                    if vict is None or (s.req.priority, -s.seq) < \
                            (slots[vict].req.priority, -slots[vict].seq):
                        vict = i
                if vict is None:
                    return
                if eng.scfg.preempt_policy == "evict":
                    evict_to_requeue(vict)
                else:
                    try:
                        park_resident(vict)
                    except faults.InjectedFault:
                        return   # victim stays resident; retry next iter
                self.preemptions += 1

        def resume_ready_parked():
            """Fill free slots with parked records, highest priority first
            (earliest-parked within a class), unless a STRICTLY higher
            pending class is eligible — parked work outranks new
            admissions of its own class (it is sunk work: pages held,
            tokens committed).  The slot an in-flight admission reserved
            is not up for grabs."""
            while self.parked:
                reserved = self._active.slot \
                    if self._active is not None else -1
                free = next((i for i in range(b)
                             if slots[i] is None and i != reserved), None)
                if free is None:
                    return
                best_pend = None
                for r in self.pending:
                    if self._eligible(r) and (best_pend is None
                                              or r.priority > best_pend):
                        best_pend = r.priority
                rec = min(self.parked,
                          key=lambda rc: (-rc.req.priority, rc.parked_step))
                if best_pend is not None and best_pend > rec.req.priority:
                    return
                self.parked.remove(rec)
                resume_parked(rec, free)

        def reclaim_parked_pages(req: Request) -> bool:
            """A page-stalled admission may reclaim pages from a PARKED
            victim of strictly lower priority — parked sunk work never
            starves a waiting higher class — or from ANY parked record
            when nothing is resident (held pages with an empty arena
            would otherwise deadlock the queue).  Destructive: the
            victim's pages release and it requeues from scratch, exactly
            an evict-to-requeue."""
            none_resident = not any(s is not None for s in slots)
            cands = [rec for rec in self.parked
                     if rec.req.priority < req.priority or none_resident]
            if not cands:
                return False
            rec = min(cands, key=lambda rc: (rc.req.priority,
                                             -rc.parked_step))
            self.parked.remove(rec)
            rec.ptab.release_all()
            transition(rec.req, RequestState.QUEUED)
            rec.req.not_before_step = 0
            self.pending.append(rec.req)
            self.evictions += 1
            if audit_on:
                self.audit_serving_state()
            return True

        def sweep_deadlines_and_cancels():
            """Honor cancel() and expired deadlines in EVERY phase through
            the one teardown path.  Runs at each iteration boundary — a
            request is never torn down mid-splice."""
            for idx in range(len(self.pending) - 1, -1, -1):
                req = self.pending[idx]
                state = _overdue(req)
                if state is not None:
                    del self.pending[idx]
                    self._terminate(req, state, _overdue_error(req, state),
                                    issued)
            adm = self._active
            if adm is not None:
                state = _overdue(adm.req)
                if state is not None:
                    teardown_admission(adm)
                    self._active = None
                    self._terminate(adm.req, state,
                                    _overdue_error(adm.req, state), issued)
            # parked requests honor cancel/deadline too: release the held
            # pages (that IS the whole teardown — no slot, no pins) and
            # flush the partial stream
            for idx in range(len(self.parked) - 1, -1, -1):
                rec = self.parked[idx]
                state = _overdue(rec.req)
                if state is not None:
                    del self.parked[idx]
                    rec.ptab.release_all()
                    self._terminate(rec.req, state,
                                    _overdue_error(rec.req, state), issued,
                                    partial=(rec.out, len(rec.req.prompt)))
                    if audit_on:
                        self.audit_serving_state()
            for i in range(b):
                if slots[i] is None:
                    continue
                req = slots[i].req
                state = _overdue(req)
                if state is not None:
                    out = list(slots[i].out)
                    clear_slot(i)
                    self._terminate(req, state, _overdue_error(req, state),
                                    issued, partial=(out, len(req.prompt)))

        def _overdue(req: Request) -> Optional[RequestState]:
            if req.cancel_requested:
                return RequestState.CANCELLED
            if req.deadline_step is not None \
                    and self.steps >= req.deadline_step:
                return RequestState.TIMED_OUT
            if req.deadline_time is not None \
                    and self._clock() >= req.deadline_time:
                return RequestState.TIMED_OUT
            return None

        def _overdue_error(req: Request, state: RequestState):
            if state is RequestState.CANCELLED:
                return RequestCancelled(f"req {req.req_id} cancelled")
            if req.deadline_step is not None \
                    and self.steps >= req.deadline_step:
                return RequestTimeout(
                    f"req {req.req_id} missed deadline step "
                    f"{req.deadline_step}")
            ms = (req.timeout_ms if req.timeout_ms is not None
                  else self.engine.scfg.request_timeout_ms)
            return RequestTimeout(
                f"req {req.req_id} missed wall-clock deadline ({ms:g} ms)")

        while self.pending or self._active or self.parked \
                or any(s is not None for s in slots):
            sweep_deadlines_and_cancels()
            self._refill_rate_credits()
            if prio_on:
                preempt_for_priority()
            resume_ready_parked()
            spill_parked_cold()

            # ---- prefill sweep: ≤ budget tokens of chunk work; priority
            # classes first, DRR across tenants within a class ----------
            spent = 0
            while spent < chunks_per_sweep:
                if self._active is None:
                    free = next((i for i in range(b) if slots[i] is None),
                                None)
                    if free is None:
                        break
                    req = pop_eligible()
                    if req is None:
                        break
                    if self.paged:
                        try:
                            self._active = try_reserve(req)
                        except Exception as exc:   # torn reservation
                            self._fail_or_retry(req, exc, issued)
                            continue
                        if self._active is None:  # stalled on pages, not
                            # slots: back to the head, BEFORE any evicted
                            # victims — after trying to reclaim pages from
                            # a lower-priority parked victim (ISSUE 8)
                            self.pending.appendleft(req)
                            if reclaim_parked_pages(req):
                                continue   # pages freed: retry right away
                            break
                    else:
                        self._active = _Admission(req, free,
                                                  eng.start_prefill(
                                                      req.prompt))
                    req.attempts += 1
                    transition(req, RequestState.PREFILLING)
                    self._trace_phase(req, "prefill", attempt=req.attempts)
                active = self._active
                self.prefill_chunks.append(
                    (self.steps, active.req.req_id, active.task.next_chunk,
                     sum(s is not None for s in slots)))
                csid = None if self.tracer is None else self.tracer.begin(
                    "prefill_chunk", "scheduler", req=active.req.req_id,
                    chunk=active.task.next_chunk)
                try:
                    eng.prefill_chunk_step(active.task)
                except Exception as exc:
                    # the task's own cache/scratch are lost (donated or
                    # torn) but the ARENA is untouched: release the
                    # reservation, retry-or-fail this request alone
                    if csid is not None:
                        self.tracer.end(csid, faulted=True)
                    teardown_admission(active)
                    self._active = None
                    self._fail_or_retry(active.req, exc, issued)
                    spent += 1
                    continue
                if csid is not None:
                    self.tracer.end(csid)
                spent += 1
                if active.task.done:
                    i = active.slot
                    try:
                        if self.tiered:
                            # residency first: the cold mirrors read the
                            # task's dense cache, which the splice leaves
                            # alive (only the ARENA is donated)
                            hot_row = assign_residency(active)
                            cache = eng.admit_tiered(
                                cache, active.task.cache, i,
                                active.ptab.pages, hot_row,
                                active.shared_pages,
                                active.task.prompt_len)
                        elif self.paged:
                            cache = eng.admit_paged(
                                cache, active.task.cache, i,
                                active.ptab.pages, active.shared_pages,
                                active.task.prompt_len)
                        else:
                            cache = eng.admit(cache, active.task.cache, i)
                    except Exception as exc:     # torn splice (pre-donate)
                        teardown_admission(active)
                        self._active = None
                        self._fail_or_retry(active.req, exc, issued)
                        continue
                    if self.paged:
                        tables[i] = active.ptab
                        host_table[i] = 0
                        host_table[i, :active.ptab.n_pages] = \
                            active.ptab.pages
                        dirty[0] = True
                        self._register_prefix(active)
                    # ownership of ptab just moved to tables[i]: drop the
                    # admission NOW so a teardown audit below cannot count
                    # the same table twice (resident + in-flight)
                    self._active = None
                    transition(active.req, RequestState.DECODING)
                    self._trace_phase(active.req, "decode")
                    key, sub = jax.random.split(key)
                    tok_arr, ok = eng.sample_checked(active.task.logits, sub)
                    if not ok[0]:
                        # poisoned prompt logits: this request alone fails
                        slots[i] = _Slot(active.req, out=[],
                                         seq=next(admit_seq))
                        fail_resident(i, NanLogitsError(
                            f"req {active.req.req_id}: non-finite prefill "
                            "logits"))
                        continue
                    tok0 = int(np.asarray(tok_arr)[0])
                    slots[i] = _Slot(active.req, out=[tok0],
                                     seq=next(admit_seq),
                                     drafter=NgramDrafter(
                                         list(active.req.prompt) + [tok0])
                                     if spec_q else None)
                    tokens[i] = tok0
                    positions[i] = len(active.req.prompt)
                    self.admissions.append((self.steps, i, active.req.req_id))
                    if not emit_tokens(i, 1):
                        continue
                    if len(slots[i].out) >= active.req.max_new_tokens:
                        finish(i)

            if not any(s is not None for s in slots):
                if not (self.pending or self._active or self.parked):
                    break
                if self._active is None and self.pending:
                    # arena idle and every pending request is backing off:
                    # fast-forward the step clock to the earliest gate so
                    # retry waits cannot busy-livelock an empty arena
                    nxt = min(r.not_before_step for r in self.pending)
                    if nxt > self.steps:
                        self.steps = nxt
                continue            # nothing resident yet: keep prefilling

            # ---- paged upkeep: map/COW every row's write page, then push
            # the host table to the device cache in one leaf swap ----------
            if self.paged:
                for i in range(b):
                    if slots[i] is not None:
                        # speculative window: the verify step may commit up
                        # to min(spec_window, remaining budget) tokens in
                        # one shot — map every page that span can touch
                        span = 1 if not spec_q else \
                            min(spec_q, slots[i].req.max_new_tokens
                                - len(slots[i].out))
                        try:
                            ensure_writable(i, span)
                        except HotTierThrash as exc:
                            shed_thrash(i, exc)    # load, not the request
                        except Exception as exc:   # alloc/COW fault: only
                            fail_resident(i, exc)  # row i pays
                # ---- selection-driven prefetch (ISSUE 7): warm each
                # row's PREVIOUS step's selected pages — the paper's
                # stability insight says the next selection mostly repeats
                # it (measured: benchmarks/overlap_score.py) ---------------
                prefetched: set = set()
                if self.tiered and eng.scfg.tier_prefetch:
                    for i in range(b):
                        if slots[i] is None:
                            continue
                        try:
                            for pid in sorted(prev_selected[i]):
                                if pid in pool.cold:
                                    fetch_page(pid, prev_selected[i])
                                    prefetched.add(pid)
                        except HotTierThrash:
                            break   # hot tier saturated: best-effort only
                        except faults.InjectedFault as exc:
                            fail_resident(i, exc)   # prefetch blast radius
                push_tables()
                if not any(s is not None for s in slots):
                    continue       # upkeep evicted/failed every resident

            # ---- one ragged decode step for the whole arena ---------------
            # (empty slots idle at position 0, harmlessly rewriting their
            # own row's slot-0 cache line — paged: the trash page; the SAME
            # compiled HLO serves every step and every admission pattern)
            try:
                # batch-wide fault point; BEFORE _decode donates the cache
                faults.maybe_fault("decode_step")
                if spec_q:
                    # draft-verify fault point (ISSUE 9): fires before the
                    # windowed jit call, while the cache is still whole —
                    # the whole window round retries like a decode_step
                    # fault (drafting is pure host work, re-proposing is
                    # free and deterministic)
                    faults.maybe_fault("draft_verify")
            except faults.InjectedFault:
                # nothing ran: retry the whole step, bounded so a rate-1.0
                # schedule cannot spin forever
                self.step_faults += 1
                fault_streak += 1
                if fault_streak > self.engine.scfg.max_request_retries:
                    raise
                continue
            fault_streak = 0
            # ISSUE 10: step span + the live rows' context lengths, read
            # BEFORE the step commits (the traffic accountant reconciles
            # the §4.5 terms at exactly the positions the selection ran at)
            tr = self.tracer
            dsid = None if tr is None else tr.begin(
                "verify_window" if spec_q else "decode_step", "scheduler",
                step=self.steps,
                n_live=sum(s is not None for s in slots))
            live_pos = [int(positions[i]) for i in range(b)
                        if slots[i] is not None]
            if spec_q:
                # ---- speculative verify window (ISSUE 9): ONE latent
                # selection + ONE windowed reconstruction serves the
                # pending token plus spec_q-1 prompt-lookup drafts; greedy
                # verify accepts the longest matching prefix and the
                # masked commit writes ONLY accepted positions, so cache
                # bytes and the token stream stay bit-identical to
                # sequential decode whatever the drafts were --------------
                wt = np.zeros((b, spec_q), np.int32)
                for i in range(b):
                    if slots[i] is not None:
                        wt[i, 0] = tokens[i]
                        wt[i, 1:] = slots[i].drafter.propose(spec_q - 1)
                win_logits, aux = eng._decode_window(
                    jnp.asarray(wt), cache, jnp.asarray(positions))
                live = [i for i in range(b) if slots[i] is not None]
                pick = faults.maybe_pick("nan_logits", len(live))
                if pick is not None:
                    # poison ONE live row's window logits — the finiteness
                    # verdict must confine the blast radius to that row
                    win_logits = win_logits.at[live[pick]].set(jnp.nan)
                wl = np.asarray(win_logits)                   # (B, Q, V)
                preds = wl.argmax(axis=-1).astype(np.int32)   # (B, Q)
                finite = np.isfinite(wl).all(axis=(1, 2))
                n_matched = np.cumprod(
                    wt[:, 1:] == preds[:, :-1], axis=1).sum(axis=1)
                n_commit = np.zeros((b,), np.int32)
                emitted: List[List[int]] = [[] for _ in range(b)]
                for i in range(b):
                    if slots[i] is None or not finite[i]:
                        continue
                    left = slots[i].req.max_new_tokens - len(slots[i].out)
                    n_emit = int(min(n_matched[i] + 1, left))
                    row = [int(wt[i, 1 + k]) for k in range(n_emit - 1)]
                    row.append(int(preds[i, n_emit - 1]))
                    emitted[i] = row
                    n_commit[i] = n_emit
                # the committed window slots are the PENDING token plus the
                # accepted drafts; the last emitted token becomes the new
                # pending token (its KV lands next round).  Rejected and
                # idle rows commit nothing (OOB-drop scatters).
                cache = eng._commit_window(cache, aux,
                                           jnp.asarray(positions),
                                           jnp.asarray(n_commit))
                self.steps += 1
                self.spec_rounds += 1
                if self.traffic is not None:
                    self.traffic.observe_decode(eng, cache, live_pos,
                                                q_len=spec_q)
                for i in range(b):
                    if slots[i] is None:
                        continue
                    if not finite[i]:
                        fail_resident(i, NanLogitsError(
                            f"req {slots[i].req.req_id}: non-finite window "
                            f"logits at step {self.steps}"))
                        continue
                    row = emitted[i]
                    self.spec_proposed += spec_q - 1
                    self.spec_accepted += min(int(n_matched[i]),
                                              len(row) - 1)
                    self.spec_committed += len(row)
                    slots[i].out.extend(row)
                    slots[i].drafter.extend(row)
                    tokens[i] = row[-1]
                    positions[i] += len(row)
                    if not emit_tokens(i, len(row)):
                        continue
                    if len(slots[i].out) >= slots[i].req.max_new_tokens:
                        finish(i)
            else:
                if self.tiered:
                    logits = tiered_decode(prefetched)
                    if logits is None:  # fetch faults tore every row down
                        if dsid is not None:
                            tr.end(dsid, aborted=True)
                        continue
                else:
                    logits, cache = eng._decode(
                        jnp.asarray(tokens), cache, jnp.asarray(positions))
                live = [i for i in range(b) if slots[i] is not None]
                pick = faults.maybe_pick("nan_logits", len(live))
                if pick is not None:
                    # poison ONE live row's logits — the blast radius the
                    # sample_checked verdict must confine to that row
                    logits = logits.at[live[pick]].set(jnp.nan)
                key, sub = jax.random.split(key)
                tok_arr, ok = eng.sample_checked(logits, sub)
                new_toks = np.asarray(tok_arr)
                self.steps += 1
                if self.traffic is not None:
                    # tiered fetch-and-rerun rounds re-stream the same
                    # terms; the ledger (and so this reconciliation) is
                    # per COMMITTED step — PCIe bytes are accounted at the
                    # fetch/spill sites themselves
                    self.traffic.observe_decode(eng, cache, live_pos)
                for i in range(b):
                    if slots[i] is None:
                        continue
                    if not ok[i]:
                        fail_resident(i, NanLogitsError(
                            f"req {slots[i].req.req_id}: non-finite logits "
                            f"or out-of-vocab token at step {self.steps}"))
                        continue
                    slots[i].out.append(int(new_toks[i]))
                    tokens[i] = new_toks[i]
                    positions[i] += 1
                    if not emit_tokens(i, 1):
                        continue
                    if len(slots[i].out) >= slots[i].req.max_new_tokens:
                        finish(i)
            if dsid is not None:
                tr.end(dsid)
            if self.paged:
                row = {
                    "step": self.steps,
                    "pages_in_use": self.pool.pages_in_use,
                    "pages_free": self.pool.pages_free,
                    "prefix_hits": self.prefix_hits,
                    "cow_copies": self.cow_copies,
                    "admission_stalls": self.admission_stalls,
                    "evictions": self.evictions,
                    "parked": len(self.parked),
                    "parks": self.parks,
                    "resumes": self.resumes,
                    "preemptions": self.preemptions,
                    "prefix_entries": len(self.prefix_index.entries)
                    if self.prefix_index else 0,
                }
                if self.tiered:
                    row.update({
                        "host_pages": pool.host_pages,
                        "fetch_hits": self.fetch_hits,
                        "prefetch_hits": self.prefetch_hits,
                        "cold_misses": self.cold_misses,
                        "spills": pool.spills,
                    })
                self.pool_gauges.append(row)
            # gauges are point-in-time samples: publish at 1/4 step rate
            # (scrape intervals dwarf 4 steps) — the unconditional publish
            # at drain below keeps end-state reads exact
            if self._metrics_installed and self.steps % 4 == 0:
                self._publish_gauges()
            if audit_on and self.steps % self.engine.scfg.audit_every == 0:
                self.audit_serving_state(
                    self.pool_gauges[-1] if self.pool_gauges else None)
            if on_step:
                on_step(self, self.steps)
        self._publish_gauges()
        return issued

    # ------------------------------------------------------ telemetry (10)

    @staticmethod
    def _mirror_nbytes(mirror: dict) -> int:
        """ACTUAL bytes of a host page mirror ({seg: {field: np array}}) —
        the measured side of the tiered PCIe ledger term."""
        return sum(int(a.nbytes)
                   for seg in mirror.values() for a in seg.values())

    def _note_transfer(self, kind: str, mirror: dict,
                       sid: Optional[int]) -> None:
        """Account one host↔HBM page transfer: close its span with the
        measured byte count and feed the traffic accountant."""
        if self.tracer is None and self.traffic is None:
            return
        nbytes = self._mirror_nbytes(mirror)
        if sid is not None:
            self.tracer.end(sid, bytes=nbytes)
        if self.traffic is not None:
            self.traffic.observe_transfer(kind, 1, nbytes)

    def _publish_gauges(self) -> None:
        """Refresh the registry's point-in-time gauges (occupancy, queue
        depths, tenant fairness).  Cumulative counts live in the counters
        behind the ``_CounterView`` fields and never pass through here."""
        g = self.metrics
        g.gauge("serve_steps", "decode steps executed").set(self.steps)
        g.gauge("serve_pending", "requests waiting in queue").set(
            len(self.pending))
        g.gauge("serve_residents", "slots running decode").set(
            sum(s is not None for s in self._slots))
        g.gauge("serve_parked_requests", "preempt-parked residents").set(
            len(self.parked))
        if self.pool is not None:
            g.gauge("serve_pages_in_use", "refcounted pool pages").set(
                self.pool.pages_in_use)
            g.gauge("serve_pages_free", "allocatable pool pages").set(
                self.pool.pages_free)
            if self.tiered:
                g.gauge("serve_host_pages", "cold (host-mirror) pages").set(
                    self.pool.host_pages)
                g.counter("serve_tier_spills_total",
                          "HBM→host page spills").set_to(self.pool.spills)
                g.counter("serve_tier_fetches_total",
                          "host→HBM page fetches").set_to(self.pool.fetches)
        if self.prefix_index is not None:
            g.gauge("serve_prefix_entries", "live prefix-cache entries").set(
                len(self.prefix_index.entries))
        if self.tenant_gauges:
            tg = g.gauge("serve_tenant_stat",
                         "per-tenant fairness/starvation gauges",
                         labelnames=("tenant", "stat"))
            for t, gd in self.tenant_gauges.items():
                for k, v in gd.items():
                    tg.set(v, tenant=t, stat=k)

    # ---------------------------------------------------------------- audit

    def audit_serving_state(self, gauges: Optional[dict] = None) -> None:
        """Cross-structure invariant audit (ISSUE 6): prove the pool, every
        live page table (residents + in-flight admission), the prefix
        index's pins, and the exported gauges agree — conservation of
        pages, no use-after-free, no leak — plus slot↔request-state
        coherence.  Raises :class:`PagerInvariantError`.  Host-side only:
        O(pages + residents), no device sync."""
        for i, s in enumerate(self._slots):
            if s is not None and s.req.state is not RequestState.DECODING:
                raise PagerInvariantError(
                    f"slot {i} resident req {s.req.req_id} in state "
                    f"{s.req.state.value}, expected decoding")
            if self.paged and s is None and i < len(self._tables) \
                    and self._tables[i] is not None:
                raise PagerInvariantError(
                    f"slot {i} is empty but still owns a page table")
        for rec in self.parked:
            if rec.req.state is not RequestState.PARKED:
                raise PagerInvariantError(
                    f"parked req {rec.req.req_id} in state "
                    f"{rec.req.state.value}, expected parked")
            if rec.ptab is None or rec.ptab.n_pages == 0:
                raise PagerInvariantError(
                    f"parked req {rec.req.req_id} holds no pages — a park "
                    "is only legal for a paged resident")
        if not self.paged:
            return
        # parked tables join the census: a park HOLDS pages, it does not
        # hide them from conservation (ISSUE 8)
        tables = [t for t in self._tables if t is not None]
        parked_pids: List[int] = []
        for rec in self.parked:
            tables.append(rec.ptab)
            parked_pids.extend(rec.ptab.pages)
        adm = self._active
        if adm is not None and adm.ptab is not None:
            tables.append(adm.ptab)
        entries = self.prefix_index.entries if self.prefix_index else []
        audit_pager(self.pool, tables, entries, gauges=gauges,
                    parked=parked_pids)

    def _register_prefix(self, adm: _Admission) -> None:
        """Register a finished prefill's whole-page prefix for sharing.

        The entry retains the task's final cache/scratch (append-only
        resume state) and its page-boundary ring snapshots; a resumed
        registrant inherits the boundary rings it skipped from ITS entry
        (same tokens, same rings)."""
        if self.prefix_index is None:
            return
        task = adm.task
        if task.prompt_len < self.engine.scfg.page_size:
            return
        rings = dict(task.boundary_rings or {})
        if adm.entry is not None:
            for d, snap in adm.entry.boundary_rings.items():
                if d <= adm.shared_pages:
                    rings.setdefault(d, snap)
        prompt = np.asarray(task.tokens[0, :task.prompt_len], np.int32)
        entry = self.prefix_index.insert(prompt, list(adm.ptab.pages), rings,
                                         task.cache, task.scratch)
        if entry is None:
            return                # duplicate / sub-page: nothing to cap
        # entry cap: each entry retains a dense (L, 1, max_seq, ·) resume
        # snapshot beyond its pinned pages — LRU-evict past the budget so
        # entry HBM stays bounded however many distinct prompts arrive.
        # Cap AFTER the (possibly no-op) insert: a duplicate registration
        # must never cost an unrelated live entry its cache slot.
        cap = max(1, self.engine.scfg.prefix_cache_entries)
        while len(self.prefix_index.entries) > cap:
            self.prefix_index.evict(self.prefix_index.lru_entry(
                exclude=entry))

    # ---------------------------------------------------------------- static

    def _run_static(self, on_batch) -> List[Request]:
        """GPT-fast-style: drain fixed batches back to back.  Lifecycle
        support is minimal but honest: cancellations requested before a
        batch starts are honored; states move QUEUED → PREFILLING →
        DECODING → DONE around each monolithic generate."""
        issued: List[Request] = []
        # length-bucket inside the admission window (deque has no sort)
        self.pending = collections.deque(
            sorted(self.pending, key=lambda r: len(r.prompt)))
        while self.pending:
            for idx in range(len(self.pending) - 1, -1, -1):
                req = self.pending[idx]
                if req.cancel_requested:
                    del self.pending[idx]
                    self._terminate(req, RequestState.CANCELLED,
                                    RequestCancelled(
                                        f"req {req.req_id} cancelled"),
                                    issued)
            batch: List[Request] = []
            while self.pending and len(batch) < self.max_batch:
                batch.append(self.pending.popleft())
            if not batch:
                break
            for req in batch:
                req.attempts += 1
                transition(req, RequestState.PREFILLING)
                transition(req, RequestState.DECODING)
            mnt = max(r.max_new_tokens for r in batch)
            if self.engine.scfg.spec_window > 1:
                results = self.engine.generate_speculative(
                    [r.prompt for r in batch], max_new_tokens=mnt)
            else:
                results = self.engine.generate(
                    [r.prompt for r in batch], max_new_tokens=mnt)
            for req, res in zip(batch, results):
                req.result = GenerationResult(
                    res.tokens[:req.max_new_tokens], res.prompt_len,
                    min(res.steps, req.max_new_tokens))
                self._terminate(req, RequestState.DONE, issued=issued)
            if on_batch:
                on_batch(batch)
        return issued
