"""int8 error-feedback gradient compression (DESIGN §4, beyond-paper).

Under pure pjit the DP gradient reduction is implicit; to compress the
cross-replica traffic we drop to ``shard_map`` over the data axes and do the
reduction by hand:

    local grad -> (+ EF residual) -> per-tensor symmetric int8 quantize
    -> all_gather int8 codes + f32 scales over the data axes   (≈4× fewer
       bytes on the wire than an f32 ring all-reduce)
    -> dequantize + mean locally -> new residual = local - dequant(local)

The residual carries this step's quantization error into the next step
(error feedback), which keeps SGD/Adam convergence unbiased in practice.
Tensors smaller than ``MIN_COMPRESS`` elements ride the normal psum — scales
and norms dominate their traffic anyway.

``compressed_mean_grads`` is the shard_map body; ``wrap_grad_fn`` applies it
to a value_and_grad function's output inside an existing shard_map context.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import axis_size

MIN_COMPRESS = 4096


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    a = jnp.max(jnp.abs(g))
    scale = jnp.maximum(a / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_mean_grads(grads, residual, axis_names: Tuple[str, ...]):
    """Inside shard_map: mean-reduce ``grads`` over ``axis_names`` with int8
    codes on the wire.  Returns (mean_grads, new_residual).

    grads/residual: local f32 pytrees (same structure).
    """
    n = 1
    for ax in axis_names:
        n *= axis_size(ax)

    def one(g, r):
        g = g.astype(jnp.float32)
        if g.size < MIN_COMPRESS:
            return jax.lax.pmean(g, axis_names), jnp.zeros_like(g)
        gc = g + r                                 # error feedback
        q, scale = quantize_int8(gc)
        deq_local = dequantize_int8(q, scale)
        new_r = gc - deq_local                      # local quantization error
        # gather int8 codes + scales from every shard, average locally
        qg = q
        sg = scale[None]
        for ax in axis_names:
            qg = jax.lax.all_gather(qg, ax, axis=0)
            sg = jax.lax.all_gather(sg, ax, axis=0)
        qg = qg.reshape(n, *g.shape)
        sg = sg.reshape(n, *([1] * g.ndim))
        mean = jnp.mean(qg.astype(jnp.float32) * sg, axis=0)
        return mean, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_residual(params) -> dict:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if p.size >= MIN_COMPRESS
        else jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes(params, axis_size: int) -> Tuple[int, int]:
    """(compressed, uncompressed) bytes moved per reduction — bookkeeping."""
    comp = unc = 0
    for p in jax.tree.leaves(params):
        unc += 2 * p.size * 4                      # ring all-reduce ≈ 2N f32
        if p.size >= MIN_COMPRESS:
            comp += (axis_size - 1) * (p.size + 4)  # all_gather int8 + scale
        else:
            comp += 2 * p.size * 4
    return comp, unc
