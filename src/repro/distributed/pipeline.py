"""GPipe pipeline parallelism over a mesh axis (DESIGN §4, beyond-paper).

The layer stack is split into ``P`` contiguous stages mapped onto the
``pipe`` mesh axis (the 'pod' axis of the two-pod mesh: cross-pod links
carry exactly ONE (mb, seq, d) activation per tick — the point of PP at
pod scale).  Schedule is plain GPipe: M microbatches, T = M + P - 1 ticks,
bubble fraction (P-1)/T.

Implementation: ``shard_map`` manual over the pipe axis (model/data stay
auto → pjit TP/DP inside each stage), a ``lax.scan`` over ticks, and a
``ppermute`` ring push of the boundary activation each tick.  Backward is
jax autodiff through the scan + ppermute (reverse permutes), so the same
function trains.

The first stage reads microbatch embeddings; the last stage accumulates
per-microbatch mean-CE partials.  Stages are selected by masking on
``jax.lax.axis_index`` — every stage runs the same code (SPMD), with its
own slice of the stacked block params.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import axis_size


def stage_slice(tree, stage: int, n_stages: int, n_layers: int):
    """Slice stacked (L, ...) block params to one stage's layers."""
    per = n_layers // n_stages
    return jax.tree.map(lambda a: a[stage * per:(stage + 1) * per], tree)


def gpipe_loss(block_fn: Callable, embed_fn: Callable, head_loss_fn: Callable,
               axis: str = "pipe"):
    """Build a pipelined loss:  f(stage_blocks, io_params, batch) -> loss.

    block_fn(stage_blocks, x)      — run this stage's layer slice
    embed_fn(io_params, mb_batch)  — tokens -> x (stage 0 only)
    head_loss_fn(io_params, x, mb_batch) — final norm+CE (last stage only)

    stage_blocks: the CALLER passes the per-stage parameter slice via
    shard_map in_specs (leading axis = pipe).  io_params (embeddings, final
    norm) are replicated — they're small next to the blocks.
    batch: microbatched pytree with leading axis M.
    """

    def loss_fn(stage_blocks, io_params, batch):
        p = axis_size(axis)
        sid = jax.lax.axis_index(axis)
        m = jax.tree.leaves(batch)[0].shape[0]
        t_total = m + p - 1

        x0 = embed_fn(io_params, jax.tree.map(lambda a: a[0], batch))
        buf0 = jnp.zeros_like(x0)

        def tick(carry, t):
            buf, loss_sum = carry
            mb_idx = jnp.clip(t, 0, m - 1)
            mb = jax.tree.map(lambda a: a[mb_idx], batch)
            # stage 0 ingests microbatch t (if still in range)
            x_in = jnp.where(jnp.logical_and(sid == 0, t < m),
                             embed_fn(io_params, mb), buf)
            y = block_fn(stage_blocks, x_in)
            # last stage: microbatch (t - p + 1) completes this tick
            out_idx = jnp.clip(t - (p - 1), 0, m - 1)
            mb_out = jax.tree.map(lambda a: a[out_idx], batch)
            # (1,)-shaped, not scalar: rank-0 values crossing the shard_map
            # boundary as autodiff residuals trip the out-spec rank check
            # (they cannot concatenate along the pipe axis)
            mb_loss = head_loss_fn(io_params, y, mb_out).reshape(1)
            take = jnp.logical_and(sid == p - 1, t >= p - 1)
            loss_sum = loss_sum + jnp.where(take, mb_loss, 0.0)
            # push boundary activation to the next stage (ring; the wrap
            # edge P-1 -> 0 delivers zeros' worth of data that stage 0
            # overwrites with the next microbatch embedding)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % p) for i in range(p)])
            return (nxt, loss_sum), None

        (_, loss_sum), _ = jax.lax.scan(
            tick, (buf0, jnp.zeros((1,), jnp.float32)), jnp.arange(t_total))
        # everyone returns the last stage's mean loss
        loss = jax.lax.psum(
            jnp.where(sid == p - 1, loss_sum, 0.0), axis) / m
        return loss[0]

    return loss_fn


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
