"""Logical-axis sharding: models annotate activations with *logical* axis
names; a context installed by the launcher maps them to mesh axes.

This keeps model code mesh-agnostic (the same ``mlp_apply`` runs on a laptop,
a 256-chip pod, or the 512-chip two-pod mesh) while the launcher controls the
parallelism layout per (arch × shape) cell.

Logical axes
------------
  batch         global batch                  -> ("pod","data") / ("data",)
  seq           in-block sequence             -> None (full within TP block)
  residual_seq  residual stream between blocks-> "model" (Megatron SP) | None
  embed         d_model                       -> None
  heads         query heads                   -> "model"
  kv_heads      kv heads                      -> "model" when divisible
  mlp           FFN hidden                    -> "model"
  experts       MoE expert dim                -> "model"
  vocab         vocabulary                    -> "model"
  kv_seq        cached sequence (decode)      -> "model" | ("data","model")
  latent        SALS latent rank r            -> None
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ShapeConfig

_state = threading.local()


@dataclass
class ShardingCtx:
    mesh: Mesh
    rules: dict

    def spec(self, logical: Tuple[Optional[str], ...]) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
            else:
                parts.append(self.rules.get(name))
        return P(*parts)

    def sharding(self, logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: dict):
    prev = current_ctx()
    _state.ctx = ShardingCtx(mesh, rules)
    try:
        with mesh:
            yield _state.ctx
    finally:
        _state.ctx = prev


def constrain(x, logical: Tuple[Optional[str], ...]):
    """with_sharding_constraint by logical axis names; no-op outside a ctx."""
    ctx = current_ctx()
    if ctx is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank mismatch: {x.shape} vs logical {logical}")
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical))


def logical_spec(logical) -> P:
    ctx = current_ctx()
    if ctx is None:
        return P(*([None] * len(logical)))
    return ctx.spec(logical)


def axis_size(ax) -> int:
    """Static mesh-axis size inside shard_map, across jax versions:
    jax >= 0.6 exposes lax.axis_size; 0.4.x returns the int from
    core.axis_frame."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(ax)
    return jax.core.axis_frame(ax)


def mesh_axes_for(logical: str) -> Tuple[Tuple[str, ...], int]:
    """Physical mesh axes a logical axis maps to, and their combined size.

    Returns ((), 1) outside a sharding context or for an unsharded axis.
    Used by the grouped decode plan to decide whether the per-group kernels
    can run shard-locally (shard_map over these axes)."""
    ctx = current_ctx()
    if ctx is None:
        return (), 1
    rule = ctx.rules.get(logical)
    axes = (rule,) if isinstance(rule, str) else tuple(rule or ())
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    total = 1
    for a in axes:
        total *= sizes[a]
    return axes, total


# ---------------------------------------------------------------------------
# Default rule sets per run kind
# ---------------------------------------------------------------------------

def default_rules(mesh_cfg: MeshConfig, shape_cfg: Optional[ShapeConfig] = None) -> dict:
    """Logical->physical mapping for one (mesh, shape) cell."""
    axes = mesh_cfg.axis_names
    data_axes = tuple(a for a in axes if a not in ("model",))
    batch = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    rules = {
        "batch": batch,
        "seq": None,
        "residual_seq": "model" if mesh_cfg.seq_parallel else None,
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "mlp": "model",
        "experts": "model",
        "vocab": "model",
        "kv_seq": "model",
        "kv_seq_full": None,   # skip-layer full-precision cache seq axis
        "latent": None,
    }

    if shape_cfg is not None and shape_cfg.kind == "decode":
        # decode: one-token steps — residual SP is pure overhead, and the
        # query heads must be REPLICATED: the SALS cache is sequence-sharded
        # (single-head latents), so head-sharded q would force XLA to
        # all-gather every selected-K block and the skip-layer caches to
        # co-locate the contraction (§Perf iteration A1: -70% collective
        # bytes on yi-9b×decode_32k).  One tiny q all-gather per layer
        # (B×H×dh ≈ 1 MiB) replaces per-cache gathers of 16 MiB..1 GiB.
        rules["residual_seq"] = None
        rules["heads"] = None
        if shape_cfg.global_batch == 1:
            # long-context single stream: spread the cache over everything
            # (incl. the skip-layer full caches — replicated they cost
            # ~1.6 GB/layer-pair at 500k and push granite/llama4 past HBM)
            rules["batch"] = None
            rules["kv_seq"] = tuple(axes)  # e.g. ("pod","data","model")
            rules["kv_seq_full"] = None    # build_decode overrides to
            # 'model' when the replicated skip cache would bust HBM
        else:
            rules["kv_seq"] = "model"
            rules["kv_seq_full"] = "model"
    return rules


# ---------------------------------------------------------------------------
# FSDP spec derivation (train): add 'data' sharding on top of the TP specs
# ---------------------------------------------------------------------------

def fsdp_specs(spec_tree, shape_tree, mesh: Mesh,
               fsdp_axis="data"):
    """ZeRO-3-style weight sharding: for every param, shard the largest
    still-unsharded dim over ``fsdp_axis`` (when divisible).  GSPMD then
    turns the DP gradient all-reduce into reduce-scatter + all-gather and
    the optimizer state inherits the sharding (ZeRO-1 for free).

    spec_tree: pytree of PartitionSpec (TP placements from *_specs(), or
    all-replicated for the pure-FSDP strategy).
    shape_tree: matching pytree of array shapes (from jax.eval_shape).
    fsdp_axis: one mesh axis name or a tuple (composite sharding, e.g.
    ("data", "model") = 256-way ZeRO-3); tuples degrade to their divisible
    prefix per param.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = (fsdp_axis,) if isinstance(fsdp_axis, str) else tuple(fsdp_axis)

    def one(spec: P, shaped) -> P:
        shape = shaped.shape if hasattr(shaped, "shape") else tuple(shaped)
        parts = list(spec) + [None] * (len(shape) - len(spec))
        used = set()
        for p in parts:
            for a in ((p,) if isinstance(p, str) else (p or ())):
                used.add(a)
        free = tuple(a for a in axes if a not in used)
        best = None
        for i, (p, s) in enumerate(zip(parts, shape)):
            if p is not None:
                continue
            ax = list(free)
            while ax:                      # largest divisible prefix
                n = 1
                for a in ax:
                    n *= sizes[a]
                if s % n == 0 and s >= n:
                    break
                ax.pop()
            if ax and (best is None or s > shape[best[0]]):
                best = (i, ax)
        if best is not None:
            i, ax = best
            parts[i] = ax[0] if len(ax) == 1 else tuple(ax)
        return P(*parts)

    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def tree_shardings(mesh: Mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_pspecs(spec_tree, shape_tree, mesh: Mesh):
    """Drop mesh axes from placements that don't divide the array dim.

    pjit rejects unevenly-sharded *arguments* (e.g. granite's 49155 vocab
    on a 16-way axis); this trims each placement from the right until the
    dim divides, falling back to replication.  Composite placements like
    ('pod','data','model') degrade gracefully to their divisible prefix.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec: P, shaped) -> P:
        shape = shaped.shape if hasattr(shaped, "shape") else tuple(shaped)
        parts = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, p in zip(shape, parts):
            if p is None:
                out.append(None)
                continue
            axes = (p,) if isinstance(p, str) else tuple(p)
            while axes:
                n = 1
                for a in axes:
                    n *= sizes[a]
                if dim % n == 0:
                    break
                axes = axes[:-1]
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))
