from repro.distributed.sharding import (
    ShardingCtx,
    constrain,
    current_ctx,
    default_rules,
    logical_spec,
    use_sharding,
)

__all__ = [
    "ShardingCtx",
    "constrain",
    "current_ctx",
    "default_rules",
    "logical_spec",
    "use_sharding",
]
