"""End-to-end driver: train a ~100M-param model for a few hundred steps on
the synthetic corpus (with checkpointing + straggler monitoring), calibrate
SALS post-training, and serve batched requests through the scheduler with
the compressed cache — comparing quality and tokens/s against the
uncompressed engine.

    PYTHONPATH=src python examples/train_then_serve.py [--steps 300]
        [--d-model 512] [--layers 8]

~100M params needs d_model=512, 8 layers, vocab 32k (embeddings dominate);
on CPU this takes tens of minutes — the defaults below train a smaller
variant in a few minutes; pass --full-100m for the real thing.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.config import ModelConfig, SALSConfig, ServeConfig, TrainConfig
from repro.data import SyntheticCorpus, make_batches
from repro.ft import StragglerMonitor
from repro.launch.serve import calibrate
from repro.serve import Request, RequestScheduler, ServeEngine
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt/e2e")
    args = ap.parse_args()
    if args.full_100m:
        args.d_model, args.layers, args.vocab = 512, 8, 32768

    cfg = ModelConfig(
        name="e2e-demo", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 64, n_kv_heads=2,
        head_dim=64, d_ff=args.d_model * 3, vocab_size=args.vocab)
    print(f"params: {cfg.param_count() / 1e6:.1f}M")

    tcfg = TrainConfig(steps=args.steps, batch_size=args.batch_size,
                       seq_len=args.seq_len, lr=3e-3, warmup_steps=20,
                       checkpoint_every=100, log_every=25)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    print(f"corpus unigram entropy: {corpus.unigram_entropy():.3f} nats")

    state = trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg, jnp.float32)
    start = ckpt.latest_step(args.ckpt_dir) or 0
    if start:
        state, start = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed from checkpoint step {start}")
    mon = StragglerMonitor()
    t0 = time.time()
    state = trainer.train_loop(
        cfg, tcfg, state=state, step_fn=trainer.make_train_step(cfg, tcfg),
        batches=make_batches(corpus, tcfg.batch_size, tcfg.seq_len, start),
        start_step=start, ckpt_dir=args.ckpt_dir, straggler=mon)
    steps_run = tcfg.steps - start
    print(f"trained {steps_run} steps in {time.time() - t0:.0f}s; "
          f"stragglers flagged: {len(mon.flags)}")

    # ---- post-training SALS calibration (paper §5.1) -----------------------
    sals = SALSConfig(rank_ratio=0.25, score_ratio=0.5, n_critical=48,
                      n_sink=4, n_recent=16, v_bits=8,
                      v_group=min(64, cfg.kv_dim),
                      skip_layers_front=1, skip_layers_back=1)
    projectors = calibrate(state["params"], cfg, sals, corpus,
                           n_sequences=16, seq_len=args.seq_len)
    from repro.core.latent_cache import cache_bytes_per_token
    print(f"SALS calibrated: rank {sals.rank(cfg.kv_dim)}/{cfg.kv_dim}, "
          f"U_r stored {projectors['u'].dtype}; LatentKVCache stores "
          f"{cache_bytes_per_token(cfg, sals):.0f} B/token/layer "
          f"vs {4 * cfg.kv_dim} full")

    # ---- serve through the batched scheduler -------------------------------
    # "sals25-g2" runs the grouped decode layout (per-slab top-k + LSE
    # merge — what a sequence-sharded mesh runs), via the same fused path
    results = {}
    for name, proj, s, groups in (
            ("full", None, SALSConfig(enabled=False), 1),
            ("sals25", projectors, sals, 1),
            ("sals25-g2", projectors, sals, 2)):
        eng = ServeEngine(state["params"], proj, cfg,
                          ServeConfig(max_seq_len=2 * args.seq_len,
                                      max_batch=4, sals=s), n_groups=groups)
        sched = RequestScheduler(eng)
        for i in range(8):
            sched.submit(Request(corpus.batch(70_000 + i, 1, 64)["tokens"][0],
                                 max_new_tokens=24))
        t0 = time.time()
        done = sched.run()
        dt = time.time() - t0
        toks = sum(r.result.steps for r in done)
        results[name] = done
        print(f"{name}: {toks} tokens in {dt:.1f}s -> {toks / dt:.1f} tok/s")

    for name in ("sals25", "sals25-g2"):
        agree = np.mean([np.mean(a.result.tokens == b.result.tokens)
                         for a, b in zip(results["full"], results[name])])
        print(f"greedy token agreement ({name} vs full): {agree:.1%}")


if __name__ == "__main__":
    main()
