"""Fault-tolerance demo: a training run that CRASHES twice mid-flight and
recovers from atomic checkpoints via the supervisor, finishing with the
exact same weights as an uninterrupted run (deterministic data order).

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.config import TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCorpus
from repro.ft import Supervisor
from repro.train import trainer

CKPT = "artifacts/ckpt/ft-demo"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_config("qwen2-1.5b").reduced(n_layers=2, vocab_size=512)
    tcfg = TrainConfig(steps=30, batch_size=8, seq_len=64, lr=2e-3,
                       checkpoint_every=5, log_every=10)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    crashes = {"at": [8, 19]}     # steps where a "node" dies

    def train(start_step: int):
        state = trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg,
                                   jnp.float32)
        if start_step:
            state, start_step = ckpt.restore(CKPT, state)
            print(f"  -> resumed from step {start_step}")
        step = jax.jit(trainer.make_train_step(cfg, tcfg))
        for i in range(start_step, tcfg.steps):
            if crashes["at"] and i == crashes["at"][0]:
                crashes["at"].pop(0)
                raise RuntimeError(f"simulated hardware fault at step {i}")
            batch = jax.tree.map(jnp.asarray,
                                 corpus.batch(i, tcfg.batch_size,
                                              tcfg.seq_len))
            state, m = step(state, batch)
            if (i + 1) % tcfg.checkpoint_every == 0:
                ckpt.save(CKPT, i + 1, state, keep=2)
            if i % tcfg.log_every == 0:
                print(f"  step {i}: loss={float(m['loss']):.4f}")
        return state

    sup = Supervisor(max_restarts=4)
    t0 = time.time()
    state = sup.run(lambda _: train(ckpt.latest_step(CKPT) or 0))
    print(f"finished with {sup.restarts} restarts in {time.time()-t0:.0f}s; "
          f"checkpoints kept: {ckpt.list_checkpoints(CKPT)}")

    # verify bit-identical to an uninterrupted run
    ref = trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg, jnp.float32)
    step = jax.jit(trainer.make_train_step(cfg, tcfg))
    for i in range(tcfg.steps):
        batch = jax.tree.map(jnp.asarray,
                             corpus.batch(i, tcfg.batch_size, tcfg.seq_len))
        ref, _ = step(ref, batch)
    deltas = [float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(state["params"]),
                  jax.tree.leaves(ref["params"]))]
    print(f"max param delta vs uninterrupted run: {max(deltas):.2e} "
          f"({'EXACT RECOVERY' if max(deltas) < 1e-5 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
