"""Run one reduced train step + (decoders) one SALS decode step for EVERY
assigned architecture — the '--arch' selector demo.

    PYTHONPATH=src python examples/multi_arch_smoke.py [--arch yi-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import SALSConfig, TrainConfig
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import calibration as cal
from repro.models import transformer as tf
from repro.train import trainer


def run_arch(arch: str) -> None:
    cfg = get_config(arch).reduced()
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    tcfg = TrainConfig(steps=1, batch_size=2, seq_len=64)
    state = trainer.init_state(key, cfg, tcfg, jnp.float32)

    if cfg.family == "encoder":
        batch = {"frames": jax.random.normal(key, (2, 64, cfg.d_model)) * .1,
                 "labels": jax.random.randint(key, (2, 64), 0,
                                              cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(key, (2, 64), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                              (2, 64), 0, cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                key, (2, cfg.vision_patches, cfg.d_model)) * 0.1
    step = jax.jit(trainer.make_train_step(cfg, tcfg))
    state, m = step(state, batch)
    line = f"{arch:26s} [{cfg.family:7s}] train loss={float(m['loss']):7.3f}"

    if cfg.is_decoder:
        sals = None
        proj = None
        if cfg.has_attention:
            sals = SALSConfig(rank_ratio=0.25, n_critical=8, n_sink=2,
                              n_recent=4, v_group=32,
                              skip_layers_front=1, skip_layers_back=1)
            proj = cal.random_layer_projectors(key, cfg, sals, cfg.n_layers)
        pf_batch = {k: v for k, v in batch.items() if k != "labels"}
        last, cache = tf.prefill(state["params"], proj, cfg, sals, pf_batch,
                                 max_seq=512)
        nxt = jnp.argmax(last, -1).astype(jnp.int32)
        pos = 64 + (cfg.vision_patches if cfg.family == "vlm" else 0)
        lg, _ = tf.decode_step(state["params"], proj, cache, nxt,
                               jnp.int32(pos), cfg, sals)
        mode = "sals" if sals else "recurrent"
        line += f"  decode[{mode}] ok"
    else:
        line += "  (encoder: no decode)"
    print(line + f"  ({time.time() - t0:.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="", choices=[""] + ASSIGNED_ARCHS)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    for arch in archs:
        run_arch(arch)


if __name__ == "__main__":
    main()
