"""Quickstart: the SALS pipeline end to end on a tiny model, in one file.

    PYTHONPATH=src python examples/quickstart.py

1. build a reduced llama-family model (yi-9b geometry, tiny dims)
2. calibrate the latent projector on synthetic pre-RoPE keys (paper §4.2)
3. prefill a prompt into the typed ``LatentKVCache`` (a registered-pytree
   dataclass — compression bookkeeping derives from its field dtypes)
4. decode with sparse attention in latent space (paper Algorithm 1) — both
   the paper-faithful global top-k and the grouped (sequence-sharded)
   layout, which run through the SAME fused decode path
5. compare against the uncompressed full-attention decode
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SALSConfig, ServeConfig
from repro.configs import get_config
from repro.core.latent_cache import LatentKVCache, cache_bytes_per_token
from repro.data import SyntheticCorpus
from repro.launch.serve import calibrate
from repro.models import transformer as tf
from repro.serve import ServeEngine


def main():
    cfg = get_config("yi-9b").reduced()
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model} "
          f"H={cfg.n_heads}/{cfg.n_kv_heads}kv)")
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg, jnp.float32)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)

    # --- SALS-25%: rank r = kv_dim/4, scores on r* = r/2, top-16 tokens ----
    sals = SALSConfig(rank_ratio=0.25, score_ratio=0.5, n_critical=16,
                      n_sink=2, n_recent=8, v_bits=8, v_group=32,
                      skip_layers_front=1, skip_layers_back=1)
    t0 = time.time()
    projectors = calibrate(params, cfg, sals, corpus, n_sequences=8,
                           seq_len=64)
    r = sals.rank(cfg.kv_dim)
    print(f"calibrated U_r: rank {r}/{cfg.kv_dim} per layer, "
          f"stored {projectors['u'].dtype} ({time.time() - t0:.1f}s)")
    # bookkeeping derives from the typed cache's field shapes/dtypes
    bpt = cache_bytes_per_token(cfg, sals)
    print(f"cache: {bpt:.0f} B/token/layer vs {4 * cfg.kv_dim} B full  "
          f"(={4 * cfg.kv_dim / bpt:.1f}x)")
    shapes = jax.eval_shape(lambda: LatentKVCache.init(cfg, sals, 1, 1, 128))
    print(f"LatentKVCache fields: k_lat{shapes.k_lat.shape[1:]} "
          f"{shapes.k_lat.dtype}, v_q{shapes.v_q.shape[1:]} "
          f"{shapes.v_q.dtype} (+ scales, sink/recent rings)")

    prompts = [corpus.batch(100 + i, 1, 48)["tokens"][0] for i in range(2)]
    engines = {
        "full": ServeEngine(params, None, cfg, ServeConfig(
            max_seq_len=128, sals=SALSConfig(enabled=False))),
        "sals": ServeEngine(params, projectors, cfg, ServeConfig(
            max_seq_len=128, sals=sals)),
        # grouped layout (n_groups rides as cache metadata): what a
        # kv_seq-sharded deployment runs, same fused kernels per slab
        "sals-g2": ServeEngine(params, projectors, cfg, ServeConfig(
            max_seq_len=128, sals=sals), n_groups=2),
    }
    outs = {}
    for name, eng in engines.items():
        t0 = time.time()
        outs[name] = eng.generate(prompts, max_new_tokens=12)
        print(f"{name}: {[r.tokens.tolist() for r in outs[name]]} "
              f"({time.time() - t0:.1f}s)")
    for name in ("sals", "sals-g2"):
        agree = np.mean([np.mean(a.tokens == b.tokens)
                         for a, b in zip(outs["full"], outs[name])])
        print(f"token agreement full vs {name}: {agree:.0%} "
              f"(random weights -> diffuse attention; see "
              f"examples/train_then_serve.py for the trained-model run)")


if __name__ == "__main__":
    main()
