"""Quickstart: the SALS pipeline end to end on a tiny model, in one file.

    PYTHONPATH=src python examples/quickstart.py

1. build a reduced llama-family model (yi-9b geometry, tiny dims)
2. calibrate the latent projector on synthetic pre-RoPE keys (paper §4.2)
3. prefill a prompt into the compressed latent cache
4. decode with sparse attention in latent space (paper Algorithm 1)
5. compare against the uncompressed full-attention decode
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SALSConfig, ServeConfig
from repro.configs import get_config
from repro.core import latent_cache as lc
from repro.data import SyntheticCorpus
from repro.launch.serve import calibrate
from repro.models import transformer as tf
from repro.serve import ServeEngine


def main():
    cfg = get_config("yi-9b").reduced()
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model} "
          f"H={cfg.n_heads}/{cfg.n_kv_heads}kv)")
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg, jnp.float32)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)

    # --- SALS-25%: rank r = kv_dim/4, scores on r* = r/2, top-16 tokens ----
    sals = SALSConfig(rank_ratio=0.25, score_ratio=0.5, n_critical=16,
                      n_sink=2, n_recent=8, v_bits=8, v_group=32,
                      skip_layers_front=1, skip_layers_back=1)
    t0 = time.time()
    projectors = calibrate(params, cfg, sals, corpus, n_sequences=8,
                           seq_len=64)
    r = sals.rank(cfg.kv_dim)
    print(f"calibrated U_r: rank {r}/{cfg.kv_dim} per layer "
          f"({time.time() - t0:.1f}s)")
    print(f"cache: {lc.cache_bytes_per_token(cfg, sals):.0f} B/token/layer "
          f"vs {4 * cfg.kv_dim} B full  "
          f"(={4 * cfg.kv_dim / lc.cache_bytes_per_token(cfg, sals):.1f}x)")

    prompts = [corpus.batch(100 + i, 1, 48)["tokens"][0] for i in range(2)]
    engines = {
        "full": ServeEngine(params, None, cfg, ServeConfig(
            max_seq_len=128, sals=SALSConfig(enabled=False))),
        "sals": ServeEngine(params, projectors, cfg, ServeConfig(
            max_seq_len=128, sals=sals)),
    }
    outs = {}
    for name, eng in engines.items():
        t0 = time.time()
        outs[name] = eng.generate(prompts, max_new_tokens=12)
        print(f"{name}: {[r.tokens.tolist() for r in outs[name]]} "
              f"({time.time() - t0:.1f}s)")
    agree = np.mean([np.mean(a.tokens == b.tokens)
                     for a, b in zip(outs["full"], outs["sals"])])
    print(f"token agreement full vs SALS-25%: {agree:.0%} "
          f"(random weights -> diffuse attention; see "
          f"examples/train_then_serve.py for the trained-model comparison)")


if __name__ == "__main__":
    main()
