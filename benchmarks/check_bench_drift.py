"""CI drift check: the MODELED sections of the committed BENCH_attention.json
must match what the traffic model in benchmarks/memory_access.py computes
TODAY.

The ledger in ROADMAP.md and the perf story in the benchmarks both quote
numbers out of BENCH_attention.json; if someone edits the byte model (or
the cache layout it derives from — LatentKVCache field shapes/dtypes feed
``cache_bytes_per_token``) without re-running ``benchmarks/attention_latency.py``,
the committed file silently lies.  This script recomputes the pure-model
sections ("traffic_model", "prefill_traffic_model" — NOT the wall-clock
"measured_cpu" rows, which legitimately vary per machine) and exits
non-zero on any mismatch.

    PYTHONPATH=src python -m benchmarks.check_bench_drift     # repo root

Fix a failure by re-running ``PYTHONPATH=src python -m
benchmarks.attention_latency`` (module form — the benchmarks package needs
the repo root on sys.path) and committing the refreshed BENCH_attention.json.
"""
from __future__ import annotations

import json
import sys

from benchmarks.attention_latency import (BENCH_JSON,
                                          fault_degradation_rows,
                                          paged_capacity_rows,
                                          prefill_traffic_rows,
                                          speculative_traffic_rows,
                                          tiered_capacity_rows,
                                          traffic_model_rows)

MODELED_SECTIONS = {
    "traffic_model": traffic_model_rows,
    "prefill_traffic_model": prefill_traffic_rows,
    "paged_capacity_model": paged_capacity_rows,
    "tiered_capacity_model": tiered_capacity_rows,
    "fault_degradation_model": fault_degradation_rows,
    "speculative_traffic_model": speculative_traffic_rows,
}

# measured (not recomputable here) but REQUIRED: the step-to-step
# selection-stability cell written by ``benchmarks/overlap_score.py`` is
# the tiered prefetcher's hit-rate model, the per-class SLO and
# speculative-decode cells written by ``benchmarks/throughput.py`` are the
# scheduling-policy story (FIFO vs evict vs park) and the verify-window
# acceptance/throughput story, and the telemetry-cost cell (also
# ``benchmarks/throughput.py``) is the ISSUE 10 gate that observability
# stays off the hot path — a re-emit must not drop any of them
MEASURED_SECTIONS = ("selection_stability", "slo_report",
                     "speculative_throughput", "obs_overhead")


def _normalize(rows):
    # round-trip through JSON so committed ints/floats compare like for like
    return json.loads(json.dumps(rows))


def main() -> int:
    if not BENCH_JSON.exists():
        print(f"DRIFT: {BENCH_JSON} is missing — run "
              "'PYTHONPATH=src python -m benchmarks.attention_latency' "
              "and commit it")
        return 1
    committed = json.loads(BENCH_JSON.read_text())
    bad = False
    for section, compute in MODELED_SECTIONS.items():
        want = _normalize(compute())
        got = committed.get(section)
        if got != want:
            bad = True
            print(f"DRIFT: BENCH_attention.json[{section!r}] no longer "
                  "matches benchmarks/memory_access.py")
            for i, (w, g) in enumerate(zip(want, got or [])):
                if w != g:
                    print(f"  row {i}:\n    model now: {w}\n    committed: {g}")
            if got is not None and len(got) != len(want):
                print(f"  row count: model now {len(want)}, "
                      f"committed {len(got)}")
        else:
            print(f"ok: {section} ({len(want)} rows)")
    measured_by = {"selection_stability": "benchmarks.overlap_score",
                   "slo_report": "benchmarks.throughput",
                   "speculative_throughput": "benchmarks.throughput",
                   "obs_overhead": "benchmarks.throughput"}
    for section in MEASURED_SECTIONS:
        got = committed.get(section)
        if not got:
            bad = True
            print(f"DRIFT: BENCH_attention.json[{section!r}] is missing/"
                  f"empty — run 'PYTHONPATH=src python -m "
                  f"{measured_by[section]}' to measure it")
        else:
            print(f"ok: {section} present ({len(got)} rows, measured)")
    for row in committed.get("obs_overhead") or []:
        if row.get("overhead_pct", 0) > row.get("budget_pct", 0):
            bad = True
            print(f"DRIFT: obs_overhead {row.get('mode')!r} measured "
                  f"{row.get('overhead_pct')}% > budget "
                  f"{row.get('budget_pct')}% — telemetry has crept onto "
                  "the hot path")
    # the telemetry exporters themselves are drift-checked in-process: an
    # exported snapshot / Prometheus page that stops validating would break
    # every scrape, so it fails CI here rather than in production
    from repro.obs.metrics import (MetricsRegistry, validate_prometheus,
                                   validate_snapshot)
    reg = MetricsRegistry()
    reg.counter("drift_check_total", "exporter self-test").inc()
    reg.gauge("drift_check_gauge", "exporter self-test",
              labelnames=("tenant",)).set(2.0, tenant="t0")
    reg.histogram("drift_check_ms", "exporter self-test").observe(3.0)
    errs = validate_snapshot(reg.snapshot()) + \
        validate_prometheus(reg.to_prometheus())
    if errs:
        bad = True
        print("DRIFT: telemetry exporter schema self-test failed:")
        for e in errs:
            print(f"  {e}")
    else:
        print("ok: obs exporters validate (snapshot + prometheus)")
    if bad:
        print("re-run: PYTHONPATH=src python -m benchmarks.attention_latency")
        return 1
    print("BENCH_attention.json modeled sections are in sync")
    return 0


if __name__ == "__main__":
    sys.exit(main())
