"""Paper Figure 2 — overlap score across layers (pre-RoPE latent top-k vs
full attention mass), measured on the repo-trained model with calibrated
projectors.  The paper's claim: >90% for middle layers, <50% for layers 0-1
(which motivates skip_layers_front=2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.launch.serve import collect_pre_rope_keys
from repro.models import transformer as tf
from repro.models.attention import qkv_proj
from repro.models.layers import rmsnorm_apply
from benchmarks import common


def layer_overlap(cfg, params, proj, corpus, sals, pos: int = 63,
                  n_batches: int = 4):
    """Mean overlap score per layer over a few evaluation prompts."""
    per_layer = []
    for l in range(cfg.n_layers):
        scores = []
        for i in range(n_batches):
            toks = jnp.asarray(corpus.batch(31_000 + i, 2, pos + 1)["tokens"])
            keys = collect_pre_rope_keys(params, cfg, {"tokens": toks})
            x, _ = tf.embed_inputs(params, cfg, {"tokens": toks})
            # run the stack up to layer l to get its input
            for j in range(l):
                bp = jax.tree.map(lambda a: a[j], params["blocks"])
                x, _, _ = tf._block_fwd(bp, x, cfg,
                                        jnp.arange(pos + 1)[None, :], 0,
                                        False)
            bp = jax.tree.map(lambda a: a[l], params["blocks"])
            h = rmsnorm_apply(bp["attn_norm"], x, cfg.norm_eps)
            q, _, _ = qkv_proj(bp["attn"], h, cfg)
            k_pre = keys[l].reshape(2, pos + 1, cfg.n_kv_heads, cfg.head_dim)
            os_ = metrics.overlap_score(q[:, -1], k_pre, proj["u"][l], cfg,
                                        sals, pos=pos)
            scores.append(np.asarray(os_))
        per_layer.append(float(np.mean(scores)))
    return per_layer


def run() -> list:
    cfg, params, corpus = common.trained_model(n_layers=4, steps=80)
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    per_layer = layer_overlap(cfg, params, proj, corpus, sals)
    rows = [("fig2", l, round(v, 4)) for l, v in enumerate(per_layer)]
    common.emit(rows, ["figure", "layer", "overlap_score"])
    mid = per_layer[1:-1]
    print(f"# middle-layer mean overlap: {np.mean(mid):.3f} "
          f"(paper: >0.9 on 7B models; proxy model is tiny)")
    return rows


if __name__ == "__main__":
    run()
