"""Paper Figure 2 — overlap score across layers (pre-RoPE latent top-k vs
full attention mass), measured on the repo-trained model with calibrated
projectors.  The paper's claim: >90% for middle layers, <50% for layers 0-1
(which motivates skip_layers_front=2).

ISSUE 7 adds the STEP-TO-STEP companion: the fraction of decode step t's
selected PAGES already selected at step t-1, per layer.  Figure 2 is a
cross-LAYER stability claim; the tiered prefetcher bets on the cross-STEP
version (warm the previous step's selection before the next decode), so
this cell — written into ``BENCH_attention.json[\"selection_stability\"]``
— is the measured hit-rate model for ``tiered_capacity_model``'s
``cold_miss_rate``."""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig
from repro.core import metrics
from repro.launch.serve import collect_pre_rope_keys
from repro.models import transformer as tf
from repro.models.attention import qkv_proj
from repro.models.layers import rmsnorm_apply
from repro.serve import ServeEngine
from benchmarks import common
from benchmarks.attention_latency import BENCH_JSON


def layer_overlap(cfg, params, proj, corpus, sals, pos: int = 63,
                  n_batches: int = 4):
    """Mean overlap score per layer over a few evaluation prompts."""
    per_layer = []
    for l in range(cfg.n_layers):
        scores = []
        for i in range(n_batches):
            toks = jnp.asarray(corpus.batch(31_000 + i, 2, pos + 1)["tokens"])
            keys = collect_pre_rope_keys(params, cfg, {"tokens": toks})
            x, _ = tf.embed_inputs(params, cfg, {"tokens": toks})
            # run the stack up to layer l to get its input
            for j in range(l):
                bp = jax.tree.map(lambda a: a[j], params["blocks"])
                x, _, _ = tf._block_fwd(bp, x, cfg,
                                        jnp.arange(pos + 1)[None, :], 0,
                                        False)
            bp = jax.tree.map(lambda a: a[l], params["blocks"])
            h = rmsnorm_apply(bp["attn_norm"], x, cfg.norm_eps)
            q, _, _ = qkv_proj(bp["attn"], h, cfg)
            k_pre = keys[l].reshape(2, pos + 1, cfg.n_kv_heads, cfg.head_dim)
            os_ = metrics.overlap_score(q[:, -1], k_pre, proj["u"][l], cfg,
                                        sals, pos=pos)
            scores.append(np.asarray(os_))
        per_layer.append(float(np.mean(scores)))
    return per_layer


def selection_stability(cfg, params, proj, corpus, sals, n_steps: int = 24,
                        prompt_len: int = 56, batch: int = 2):
    """Per-layer step-to-step page-selection stability: the fraction of
    decode step t's selected pages that step t-1 already selected,
    averaged over steps and batch rows.  Uses the PAGED decode path's own
    selection-collection probe (``collect_selection`` — the same mask the
    tiered fetch-and-rerun loop reads), so the measurement is exactly the
    oracle the prefetcher consults."""
    ps = 16
    scfg = ServeConfig(max_seq_len=128, max_new_tokens=n_steps,
                       max_batch=batch, sals=sals, prefill_chunk=8,
                       page_size=ps, prefix_cache=False)
    eng = ServeEngine(params, proj, cfg, scfg)
    mp = scfg.max_seq_len // ps
    cache = eng.init_slot_cache()
    host_table = np.zeros((batch, mp), np.int32)
    tokens = np.zeros((batch,), np.int32)
    positions = np.zeros((batch,), np.int32)
    for i in range(batch):
        prompt = corpus.batch(37_000 + i, 1, prompt_len)["tokens"][0]
        task = eng.start_prefill(prompt)
        while not task.done:
            eng.prefill_chunk_step(task)
        host_table[i] = np.arange(1 + i * mp, 1 + (i + 1) * mp)
        cache = eng.admit_paged(cache, task.cache, i, list(host_table[i]),
                                0, len(prompt))
        tokens[i] = int(np.argmax(np.asarray(task.logits)[0]))
        positions[i] = len(prompt)
    cache = eng.with_page_tables(cache, host_table)

    step = jax.jit(
        lambda t, c, p: tf.decode_step(eng.params, eng.projectors, c, t, p,
                                       cfg, eng.sals,
                                       collect_selection=True),
        donate_argnums=(1,))
    front = sals.skip_layers_front
    prev: dict = {}
    overlap_sum: dict = {}
    overlap_n: dict = {}
    for _ in range(n_steps):
        logits, cache, touched = step(jnp.asarray(tokens), cache,
                                      jnp.asarray(positions))
        layer = front
        for seg in sorted(touched):
            seg_touch = np.asarray(touched[seg])       # (ls, B, mp)
            for li in range(seg_touch.shape[0]):
                for bi in range(batch):
                    sel = set(np.nonzero(seg_touch[li, bi])[0].tolist())
                    key = (layer + li, bi)
                    if key in prev and sel:
                        hit = len(sel & prev[key]) / len(sel)
                        overlap_sum[layer + li] = \
                            overlap_sum.get(layer + li, 0.0) + hit
                        overlap_n[layer + li] = \
                            overlap_n.get(layer + li, 0) + 1
                    prev[key] = sel
            layer += seg_touch.shape[0]
        tokens = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        positions += 1
    return {l: overlap_sum[l] / overlap_n[l] for l in sorted(overlap_sum)}


def run() -> list:
    cfg, params, corpus = common.trained_model(n_layers=4, steps=80)
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    per_layer = layer_overlap(cfg, params, proj, corpus, sals)
    rows = [("fig2", l, round(v, 4)) for l, v in enumerate(per_layer)]
    common.emit(rows, ["figure", "layer", "overlap_score"])
    mid = per_layer[1:-1]
    print(f"# middle-layer mean overlap: {np.mean(mid):.3f} "
          f"(paper: >0.9 on 7B models; proxy model is tiny)")
    stab = selection_stability(cfg, params, proj, corpus, sals)
    stab_rows = [("selection-stability", l, round(v, 4))
                 for l, v in stab.items()]
    common.emit(stab_rows, ["figure", "layer", "page_stability"])
    print(f"# mean page stability: {np.mean(list(stab.values())):.3f} "
          "(tiered prefetch hit-rate bound; 1 - this feeds "
          "tiered_capacity_model cold_miss_rate)")
    # read-modify-write: the modeled sections of BENCH_attention.json are
    # owned by benchmarks/attention_latency.py — only add our cell
    payload = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() \
        else {"bench": "attention"}
    payload["selection_stability"] = [
        {"layer": l, "page_stability": round(v, 4)} for l, v in stab.items()]
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote selection_stability -> {BENCH_JSON}")
    return rows + stab_rows


if __name__ == "__main__":
    run()
