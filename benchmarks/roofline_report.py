"""§Roofline — aggregate the dry-run artifacts into the per-(arch × shape ×
mesh) roofline table (compute/memory/collective terms, bound, useful ratio)
and emit the markdown table EXPERIMENTS.md §Roofline embeds."""
from __future__ import annotations

import glob
import json
import os

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load(tag: str = "") -> list:
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(p) as f:
            d = json.load(f)
        if d.get("tag", "") != tag:
            continue
        rows.append(d)
    return rows


def table(rows, *, mesh: str = "pod16x16") -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "useful | MFU bound | peak GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for d in rows:
        if d.get("mesh") != mesh:
            continue
        if d.get("status") == "skipped":
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | "
                       f"skipped: {d['reason']} | — | — | — |\n")
            continue
        if d.get("status") != "ok":
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | "
                       f"FAILED | — | — | — |\n")
            continue
        r = d["roofline"]
        peak = (d.get("memory_analysis") or {}).get("peak_bytes")
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | {r['bound']} | "
            f"{r['useful_ratio']:.2f} | {r['mfu']:.3f} | "
            f"{(peak or 0) / 2**30:.1f} |\n")
    return "".join(out)


def run() -> list:
    rows = load()
    print(f"# {len(rows)} dry-run artifacts in {ART}")
    print(table(rows))
    ok = [d for d in rows if d.get("status") == "ok"
          and d.get("mesh") == "pod16x16"]
    if ok:
        worst = sorted(ok, key=lambda d: d["roofline"]["mfu"])[:3]
        print("# lowest-MFU cells (hillclimb candidates):")
        for d in worst:
            print(f"#   {d['arch']} × {d['shape']}: "
                  f"bound={d['roofline']['bound']} "
                  f"mfu={d['roofline']['mfu']:.4f}")
    return rows


if __name__ == "__main__":
    run()
