"""Paper Figure 4 / Appendix A — eigenspectra + Rank_l(90) of keys before
vs after RoPE.  Claim: post-RoPE keys need MORE principal components at the
same energy, so compression must happen pre-RoPE."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.launch.serve import collect_pre_rope_keys
from benchmarks import common


def run() -> list:
    cfg, params, corpus = common.trained_model(n_layers=4, steps=80)
    rows = []
    for l in range(cfg.n_layers):
        toks = jnp.asarray(corpus.batch(41_000 + l, 2, 128)["tokens"])
        keys = collect_pre_rope_keys(params, cfg, {"tokens": toks})
        k_pre = np.asarray(keys[l][0]).reshape(128, cfg.n_kv_heads,
                                               cfg.head_dim)
        r_pre, r_post, ev_pre, ev_post = metrics.rank_pre_post_rope(
            k_pre, cfg, v=90.0)
        rows.append(("fig4", l, r_pre, r_post,
                     round(float(ev_pre[0] / max(ev_pre.sum(), 1e-9)), 4),
                     round(float(ev_post[0] / max(ev_post.sum(), 1e-9)), 4)))
    common.emit(rows, ["figure", "layer", "rank90_pre_rope",
                       "rank90_post_rope", "top_eig_frac_pre",
                       "top_eig_frac_post"])
    n_up = sum(1 for r in rows if r[3] >= r[2])
    print(f"# layers with post-RoPE rank >= pre-RoPE: {n_up}/{len(rows)} "
          f"(paper: post-RoPE consistently higher)")

    # layer-adaptive rank selection (paper appendix A suggestion)
    from repro.config import SALSConfig
    from repro.core import calibration as cal
    from benchmarks.common import projectors_for, sals_settings
    sals = sals_settings(cfg, "25")
    proj = projectors_for(cfg, params, corpus, sals)
    ranks = cal.adaptive_ranks(np.asarray(proj["eigvals"]), 0.90)
    fixed = sals.rank(cfg.kv_dim)
    print(f"# adaptive Rank_l(90) per layer: {ranks} "
          f"(fixed-25% rank: {fixed}; adaptive mean "
          f"{np.mean(ranks):.1f} -> extra "
          f"{fixed / max(np.mean(ranks), 1e-9):.2f}x compression headroom)")
    return rows


if __name__ == "__main__":
    run()
