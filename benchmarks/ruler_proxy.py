"""Paper Table 5 (RULER) proxy — retrieval precision of latent-space
selection, weights-free.

RULER measures whether long-context retrieval survives compression.  The
mechanism under test is SALS's claim that latent top-k FINDS the needle:
we plant `n_needles` keys with high query-similarity at random positions
in an s-token pre-RoPE key field, project to rank-r latents with a PCA
projector fitted on the field, and measure needle recall@budget of the
truncated-latent scores (§4.3) across (seq_len × rank_ratio), the axes of
the paper's Table 5 degradation (SALS-25% ≈ baseline; 12.5% degrades on
retrieval-heavy subtasks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projection as pj
from repro.core import selection as sel
from benchmarks import common


def recall_at_budget(seq_len: int, rank_ratio: float, *, kv_dim: int = 128,
                     n_needles: int = 4, budget: int = 64, trials: int = 8,
                     true_rank: int = 40, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    hits = total = 0
    # low-rank background with a DECAYING spectrum (the paper's pre-RoPE
    # key structure, Fig. 4a): PCA rank order follows the decay
    basis = np.linalg.qr(rng.normal(size=(kv_dim, kv_dim)))[0][:true_rank]
    lam = 0.9 ** np.arange(true_rank)
    for t in range(trials):
        coef = rng.normal(size=(seq_len, true_rank)) * np.sqrt(lam)
        keys = coef @ basis + 0.02 * rng.normal(size=(seq_len, kv_dim))
        # the query-relevant direction lives in the MID-spectrum PCs
        # (components 8..32): a rank-32 projector keeps it, rank-16 /
        # score-rank-8 truncates it — the Table 5 degradation mechanism
        mid = np.zeros(true_rank)
        mid[8:32] = rng.normal(size=24)
        q_dir = mid @ basis
        q_dir /= np.linalg.norm(q_dir)
        q = q_dir + 0.2 * rng.normal(size=(kv_dim,))
        needle_pos = rng.choice(seq_len, n_needles, replace=False)
        scale = np.linalg.norm(keys, axis=1).mean()
        keys[needle_pos] = 2.0 * q_dir * scale + keys[needle_pos] * 0.3

        r = max(8, int(rank_ratio * kv_dim))
        p = pj.fit_projector(keys, r)
        lat = jnp.asarray(keys, jnp.float32) @ p["u"]
        r_star = max(8, r // 2)
        scores = sel.latent_scores(jnp.asarray(q, jnp.float32)[None],
                                   p["u"], lat[None], r_star)[0]
        top = np.asarray(jnp.argsort(-scores)[:budget])
        hits += len(set(top.tolist()) & set(needle_pos.tolist()))
        total += n_needles
    return hits / total


def run() -> list:
    rows = []
    for s in (1024, 4096, 16384):
        for rr, label in ((0.25, "SALS-25%"), (0.125, "SALS-12.5%")):
            rec = recall_at_budget(s, rr, seed=s)
            rows.append(("table5-proxy", label, s, 64, round(rec, 3)))
    common.emit(rows, ["table", "method", "seq", "budget", "needle_recall"])
    print("# paper Table 5: SALS-25% ~= baseline; 12.5% degrades on "
          "retrieval-critical subtasks (MK2) — recall should drop with "
          "rank_ratio and seq")
    return rows


if __name__ == "__main__":
    run()
