"""Paper Table 7 — end-to-end decode throughput (tokens/s), SALS engine vs
full-cache engine (the GPT-fast role), measured on the reduced model on CPU
+ v5e projection at the paper's (bs, seq) grid."""
from __future__ import annotations

import numpy as np

import time

from repro.analysis.roofline import HBM_BW
from repro.config import SALSConfig, ServeConfig
from repro.configs import get_config
from repro.serve import Request, RequestScheduler, ServeEngine
from benchmarks import common
from benchmarks.memory_access import traffic_ratio


def measured_rows():
    cfg, params, corpus = common.trained_model()
    rows = []
    for bs, ctx in [(2, 256), (4, 256)]:
        eng_full = ServeEngine(params, None, cfg,
                               ServeConfig(max_seq_len=ctx + 64,
                                           sals=SALSConfig(enabled=False)))
        tput_full = eng_full.decode_throughput(bs, ctx, n_steps=16)
        sals = common.sals_settings(cfg, "25")
        proj = common.projectors_for(cfg, params, corpus, sals)
        eng_sals = ServeEngine(params, proj, cfg,
                               ServeConfig(max_seq_len=ctx + 64, sals=sals))
        tput_sals = eng_sals.decode_throughput(bs, ctx, n_steps=16)
        rows.append(("table7-cpu", bs, ctx, round(tput_full, 1),
                     round(tput_sals, 1), round(tput_sals / tput_full, 2)))
    return rows


def projected_rows():
    """v5e projection: decode step latency ≈ (weights + KV traffic)/HBM_bw;
    SALS shrinks only the KV term (paper's observation that the weight
    stream dominates short contexts — hence 1.4x @4k but 4.5x @32k)."""
    cfg = get_config("paper-llama2-7b")
    w_bytes = cfg.param_count() * 2
    rows = []
    for bs, seq in [(8, 4096), (8, 8192), (8, 16384), (8, 32768),
                    (4, 65536)]:
        kv_full = bs * 2 * seq * cfg.kv_dim * 2 * cfg.n_layers
        t_full = (w_bytes + kv_full) / HBM_BW
        for variant in ("25", "12.5"):
            sals = SALSConfig(rank_ratio=0.25 if variant == "25" else 0.125,
                              v_bits=8 if variant == "25" else 4,
                              n_critical=1024, n_sink=16, n_recent=128,
                              v_group=64)
            ratio = traffic_ratio(cfg, sals, seq)
            t_sals = (w_bytes + kv_full * ratio) / HBM_BW
            rows.append((f"table7-v5e-SALS{variant}", bs, seq,
                         round(bs / t_full, 1), round(bs / t_sals, 1),
                         round(t_full / t_sals, 2)))
    return rows


def scheduler_rows():
    """Continuous vs static batching (ISSUE 3): wall-clock to drain a
    mixed-length request stream through the SAME SALS engine.  Continuous
    admits into freed slots between ragged decode steps; static drains
    whole batches.  The win grows with max_new_tokens variance (static pads
    every batch to its slowest member)."""
    cfg, params, corpus = common.trained_model()
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    eng = ServeEngine(params, proj, cfg,
                      ServeConfig(max_seq_len=256, max_batch=4, sals=sals))
    rows = []
    for n_req, mnt_spread in [(8, (4, 24)), (12, (2, 12))]:
        def workload():
            # fresh rng per call: both modes drain the IDENTICAL stream
            rng = np.random.default_rng(n_req)
            return [Request(corpus.batch(70_000 + i, 1,
                                         int(rng.integers(16, 48)))
                            ["tokens"][0],
                            max_new_tokens=max(1, int(rng.integers(
                                *mnt_spread))))
                    for i in range(n_req)]
        out = {}
        for mode in ("static", "continuous"):
            sched = RequestScheduler(eng, mode=mode)
            reqs = workload()
            for r in reqs:
                sched.submit(r)
            t0 = time.perf_counter()
            done = sched.run()
            dt = time.perf_counter() - t0
            toks = sum(r.result.steps for r in done)
            out[mode] = toks / dt
        rows.append(("scheduler-cpu", n_req, f"mnt{mnt_spread}",
                     round(out["static"], 1), round(out["continuous"], 1),
                     round(out["continuous"] / out["static"], 2)))
    return rows


def prefill_interleave_rows():
    """ISSUE 4: inter-token latency and TTFT when a LONG prompt arrives
    mid-decode.  "blocking" prefills the whole prompt in one sweep (chunk =
    max_seq — the pre-chunking behavior: residents stall for the full
    prompt).  "interleaved" spends prefill_token_budget tokens of chunk
    work between decode steps, so the residents' p99 inter-token gap is
    bounded by one budget's worth of chunk HLOs while the long prompt's
    TTFT stretches only modestly."""
    cfg, params, corpus = common.trained_model()
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    long_prompt = corpus.batch(90_000, 1, 160)["tokens"][0]
    rows = []
    for label, chunk, budget in (("blocking", 256, 256),
                                 ("interleaved", 16, 16)):
        eng = ServeEngine(params, proj, cfg,
                          ServeConfig(max_seq_len=256, max_batch=2,
                                      sals=sals, prefill_chunk=chunk,
                                      prefill_token_budget=budget))
        sched = RequestScheduler(eng, mode="continuous")
        # staggered budgets: the second short request stays RESIDENT through
        # the whole long-prompt prefill, so every on_step gap is a genuine
        # resident inter-token stall (no no-resident idle spans pollute p99)
        short = [Request(corpus.batch(91_000 + i, 1, 24)["tokens"][0],
                         max_new_tokens=mnt)
                 for i, mnt in enumerate((24, 96))]
        long_req = Request(long_prompt, max_new_tokens=4)
        for r in short:
            sched.submit(r)
        times = []
        state = {}

        def on_step(s, step):
            times.append(time.perf_counter())
            if step == 4 and "t_submit" not in state:
                state["t_submit"] = time.perf_counter()
                s.submit(long_req)
            if "t_first" not in state and long_req.req_id in {
                    a[2] for a in s.admissions}:
                state["t_first"] = time.perf_counter()

        sched.run(on_step=on_step)
        gaps = np.diff(np.asarray(times)) * 1e3              # ms
        ttft = (state["t_first"] - state["t_submit"]) * 1e3
        # max gap is the robust discriminator on this tiny CPU model (the
        # blocking mode's single whole-prompt sweep); p99 needs enough
        # decode steps to register it
        rows.append(("prefill-interleave-cpu", label,
                     f"chunk{chunk}/budget{budget}", round(ttft, 1),
                     round(float(np.max(gaps)), 1),
                     round(float(np.percentile(gaps, 99)), 1),
                     round(float(np.median(gaps)), 1)))
    return rows


def prefix_sharing_rows():
    """ISSUE 5: N requests sharing a long prompt prefix through the PAGED
    engine, prefix cache on vs off.  Shared admission skips the shared
    pages' chunk HLOs and stores the prefix once (pages high-water ≈
    prefix + Σ unique suffixes, not N·prompt).  On this tiny CPU model the
    wall-clock TTFT is jit-compile-dominated — the structural columns
    (chunk_hlos, pages_high_water, prefix_hits) are the discriminator; on
    real hardware the skipped chunk HLOs ARE the follower-TTFT win."""
    cfg, params, corpus = common.trained_model()
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    ps, n_req = 32, 4
    prefix = corpus.batch(95_000, 1, 96)["tokens"][0]
    prompts = [np.concatenate([prefix,
                               corpus.batch(95_100 + i, 1, 16)["tokens"][0]])
               for i in range(n_req)]
    rows = []
    for label, share in (("shared", True), ("unshared", False)):
        eng = ServeEngine(params, proj, cfg,
                          ServeConfig(max_seq_len=256, max_batch=n_req,
                                      sals=sals, prefill_chunk=16,
                                      page_size=ps, prefix_cache=share))
        sched = RequestScheduler(eng, mode="continuous")
        reqs = [Request(p, max_new_tokens=8) for p in prompts]
        t_submit = time.perf_counter()
        admit_t = {}

        def on_step(s, step):
            for _, slot, rid in s.admissions:
                admit_t.setdefault(rid, time.perf_counter())

        for r in reqs:
            sched.submit(r)
        t0 = time.perf_counter()
        sched.run(on_step=on_step)
        dt = time.perf_counter() - t0
        # follower TTFT: time to admission of the LAST same-prefix request
        last_ttft = (max(admit_t.values()) - t_submit) * 1e3 if admit_t \
            else float("nan")
        hw = max(g["pages_in_use"] for g in sched.pool_gauges)
        toks = sum(r.result.steps for r in reqs)
        rows.append(("prefix-sharing-cpu", label, n_req,
                     round(last_ttft, 1), hw, sched.prefix_hits,
                     len(sched.prefill_chunks), round(toks / dt, 1)))
    return rows


def fault_degradation_rows():
    """ISSUE 6: measured graceful degradation — drain the same request
    stream through the PAGED engine under seeded fault schedules at
    increasing per-step rates (page-alloc + decode-step + one-row NaN
    faults).  Columns: goodput (committed tok/s counting only DONE
    requests), p99 inter-token gap for surviving residents, and the
    retry/failure ledger.  The deterministic counterpart (closed-form
    attempts/goodput at the same rates) is
    ``benchmarks/memory_access.py::fault_degradation_model`` in
    ``BENCH_attention.json["fault_degradation_model"]``."""
    from repro.serve import faults
    cfg, params, corpus = common.trained_model()
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    eng = ServeEngine(params, proj, cfg,
                      ServeConfig(max_seq_len=256, max_batch=4, sals=sals,
                                  prefill_chunk=16, page_size=32,
                                  max_request_retries=2))
    rows = []
    for rate in (0.0, 0.01, 0.05):
        sched = RequestScheduler(eng, mode="continuous")
        rng = np.random.default_rng(17)
        reqs = [Request(corpus.batch(97_000 + i, 1,
                                     int(rng.integers(16, 48)))["tokens"][0],
                        max_new_tokens=int(rng.integers(8, 24)))
                for i in range(8)]
        for r in reqs:
            sched.submit(r)
        times = []
        schedule = faults.FaultSchedule(
            seed=17, rates={"page_alloc": rate, "decode_step": rate,
                            "nan_logits": rate / 2})
        t0 = time.perf_counter()
        with faults.injected(schedule):
            sched.run(on_step=lambda s, step: times.append(
                time.perf_counter()))
        dt = time.perf_counter() - t0
        done = [r for r in reqs if r.done]
        toks = sum(r.result.steps for r in done)
        gaps = np.diff(np.asarray(times)) * 1e3 if len(times) > 1 else \
            np.zeros(1)
        rows.append(("fault-degradation-cpu", rate, f"{len(done)}/8",
                     round(toks / dt, 1),
                     round(float(np.percentile(gaps, 99)), 1),
                     sched.retries, sched.step_faults, sched.failures))
    return rows


def slo_rows():
    """ISSUE 8: per-class SLO report under a mixed-priority workload —
    FIFO (priority off) vs evict-requeue vs preempt-park on the same
    PAGED engine shape.  Three long batch-class requests occupy a 2-slot
    arena; three short interactive-class requests arrive mid-generation.

    Streaming latency is measured at the client's on_token callback over
    the event sequence [submit, tok0, tok1, ...] — so queueing/preemption
    delay lands in BOTH the TTFT column and the p99 inter-event gap (what
    a streaming client actually experiences).  Under FIFO the interactive
    class waits for a drained slot (TTFT ≈ a long request's remaining
    budget); evict frees a slot immediately but re-prefills the victim
    (batch-class tokens are repaid); park frees a slot immediately AND
    keeps the victim's pages — interactive p99 drops without giving up
    goodput (DONE tokens/s over the whole episode).  Each policy runs the
    episode twice and reports the second (HLOs warm)."""
    cfg, params, corpus = common.trained_model()
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    # long batch prompts: what park holds (and evict re-prefills) is six
    # chunks' worth of pages per victim — enough for held state to matter
    # even on the tiny CPU model
    lo_prompts = [corpus.batch(98_000 + i, 1, 96)["tokens"][0]
                  for i in range(3)]
    hi_prompts = [corpus.batch(98_100 + i, 1, 16)["tokens"][0]
                  for i in range(3)]
    rows = []
    for policy in ("fifo", "evict", "park"):
        kw = {} if policy == "fifo" else dict(priority_classes=2,
                                              preempt_policy=policy)
        eng = ServeEngine(params, proj, cfg,
                          ServeConfig(max_seq_len=256, max_batch=2,
                                      sals=sals, prefill_chunk=16,
                                      page_size=32, prefill_token_budget=16,
                                      **kw))

        def episode():
            from repro.obs.trace import RequestTimeline
            sched = RequestScheduler(eng, mode="continuous")
            # the ONE stamping path (obs/trace.py): stamps[rid] is the
            # event sequence [t_submit, t_tok0, t_tok1, ...]
            timeline = RequestTimeline()

            def make(prompt, mnt, prio, tenant):
                req = Request(prompt, max_new_tokens=mnt, priority=prio,
                              tenant_id=tenant)
                timeline.attach(req)
                return req

            hi_prio = 1 if policy != "fifo" else 0
            lo = [make(p, 32, 0, "batch") for p in lo_prompts]
            hi = [make(p, 8, hi_prio, "interactive") for p in hi_prompts]
            for r in lo:
                timeline.submitted(r.req_id)
                sched.submit(r)
            arrivals = [(2, hi[0]), (4, hi[1]), (6, hi[2])]

            def on_step(s, step):
                while arrivals and step >= arrivals[0][0]:
                    _, r = arrivals.pop(0)
                    timeline.submitted(r.req_id)
                    s.submit(r)

            t0 = time.perf_counter()
            sched.run(on_step=on_step)
            dt = time.perf_counter() - t0
            done = [r for r in lo + hi if r.done]
            good = sum(r.result.steps for r in done) / dt
            out = {}
            for label, grp in (("interactive", hi), ("batch", lo)):
                ttfts, gaps = [], []
                for r in grp:
                    ts = timeline.stamps.get(r.req_id, [])
                    if len(ts) > 1:
                        # diff over [submit, tok0, ...]: queueing delay
                        # lands in BOTH ttft and the p99 gap (docstring)
                        ttfts.append((ts[1] - ts[0]) * 1e3)
                        gaps.extend(np.diff(np.asarray(ts)) * 1e3)
                out[label] = (float(np.mean(ttfts)),
                              float(np.percentile(gaps, 99)),
                              float(np.median(gaps)))
            return sched, out, good

        episode()                           # warm every HLO this policy hits
        sched, out, good = episode()
        for label in ("interactive", "batch"):
            ttft, p99, med = out[label]
            rows.append(("slo-cpu", policy, label, round(ttft, 1),
                         round(p99, 1), round(med, 1), round(good, 1),
                         sched.parks, sched.preemptions, sched.evictions))
    return rows


def speculative_rows():
    """ISSUE 9: measured speculative decode through the fused SALS path —
    one latent selection amortized over a q_len=4 verify window, on the
    same engine shape sequential runs.  Two workloads bracket the n-gram
    drafter: "repetitive" prompts (tiled token loops, the structured-output
    proxy) accept nearly every draft; "novel" corpus text sits near the
    drafter's floor.  Both runs are greedy and the episode stays inside the
    exact regime — ``n_critical`` covers every position's selectable range,
    so the window's single stale selection is the full selection and the
    speculative output is token-exact vs sequential (the ``exact`` column
    asserts it; shrinking ``n_critical`` below the range would make the
    amortized selection an approximation, like SALS itself).  Each variant
    runs twice and reports the second (HLOs warm).  The closed-form
    counterpart (bytes/accepted-token at swept acceptance) is
    ``BENCH_attention.json["speculative_traffic_model"]``."""
    import dataclasses
    cfg, params, corpus = common.trained_model()
    sals = dataclasses.replace(common.sals_settings(cfg, "25"),
                               n_critical=96)
    proj = common.projectors_for(cfg, params, corpus, sals)
    base = corpus.batch(99_000, 1, 12)["tokens"][0]
    workloads = {
        "repetitive": [np.tile(base, 6)[:32 + 8 * i].astype(base.dtype)
                       for i in range(4)],
        "novel": [corpus.batch(99_100 + i, 1, 32)["tokens"][0]
                  for i in range(4)],
    }
    mnt, q = 24, 4
    rows = []
    for label, prompts in workloads.items():
        eng_seq = ServeEngine(params, proj, cfg,
                              ServeConfig(max_seq_len=256, max_batch=4,
                                          sals=sals))
        eng_spec = ServeEngine(params, proj, cfg,
                               ServeConfig(max_seq_len=256, max_batch=4,
                                           sals=sals, spec_window=q))
        out = {}
        for mode, eng in (("sequential", eng_seq), ("speculative",
                                                    eng_spec)):
            gen = eng.generate_speculative if mode == "speculative" \
                else eng.generate
            gen(prompts, max_new_tokens=mnt)            # warm
            t0 = time.perf_counter()
            res = gen(prompts, max_new_tokens=mnt)
            dt = time.perf_counter() - t0
            toks = sum(len(r.tokens) for r in res)
            out[mode] = (toks / dt, [r.tokens for r in res])
        stats = eng_spec.spec_stats
        acc = stats["accepted_drafts"] / max(1, stats["proposed"])
        exact = all(np.array_equal(a, b) for a, b in
                    zip(out["sequential"][1], out["speculative"][1]))
        rows.append(("speculative-cpu", label, q, round(acc, 3),
                     round(stats["committed"] / max(1, stats["rounds"]), 2),
                     round(out["sequential"][0], 1),
                     round(out["speculative"][0], 1),
                     round(out["speculative"][0] / out["sequential"][0], 2),
                     exact))
    return rows


# telemetry seams the scheduler/engine hot loop consults per decode step
# when NOTHING is installed: tracer-is-None at the span sites (lifecycle,
# decode_step, transfers), traffic-is-None, _metrics_installed, the pager
# hook, engine decode_throughput's tracer check.  Counted generously (the
# real loop visits fewer on most steps).
_OBS_SEAMS_PER_STEP = 16


def obs_overhead_rows():
    """ISSUE 10: telemetry cost on the serving hot path.

    Differential wall-clock (off-vs-on drains, interleaved) was the first
    design and it cannot work here: per-drain throughput on this shared
    CPU box swings ±10-20% (scheduler preemption + frequency drift;
    ``process_time`` is worse because the multi-threaded CPU backend's
    contention shows up as extra CPU seconds), so a ≤1% bound would need
    hundreds of trials.  Both cells therefore measure ATTRIBUTION inside
    one drain, where numerator and denominator share the same noise:

    * "enabled": the full stack (registry + tracer + traffic accountant
      reconciling every decode step) runs while every telemetry entry
      point the scheduler/engine calls (span begin/end, observe_decode /
      observe_transfer, gauge publishing) is wrapped with a
      ``perf_counter`` pair; overhead_pct = telemetry seconds / drain
      seconds.  The wrapper cost lands in the numerator, so the measured
      number is an overestimate — conservative in the right direction.
    * "disabled": nothing installed, the hot path pays one attribute
      load + ``is None`` branch per seam.  The cell measures that guard
      on the live scheduler object with a ``timeit``-style loop and
      bills :data:`_OBS_SEAMS_PER_STEP` of them per executed decode
      step against the drain's wall clock.

    tok_s carries the median drain throughput per mode for context (it
    wobbles with the box; the gate rides overhead_pct).  Gate: disabled
    ≤ 1%, enabled ≤ 5%, enforced by benchmarks/check_bench_drift.py."""
    from repro import obs
    cfg, params, corpus = common.trained_model()
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    eng = ServeEngine(params, proj, cfg,
                      ServeConfig(max_seq_len=256, max_batch=4, sals=sals))

    def workload():
        rng = np.random.default_rng(23)
        return [Request(corpus.batch(96_000 + i, 1,
                                     int(rng.integers(16, 40)))["tokens"][0],
                        max_new_tokens=int(rng.integers(48, 65)),
                        tenant_id=f"tenant{i % 2}")
                for i in range(16)]

    def drain(wrap=None):
        """One full continuous-mode drain; returns (tok_s, wall_s, steps).
        ``wrap(sched)`` runs after construction so a trial can instrument
        the scheduler before the hot loop starts."""
        sched = RequestScheduler(eng, mode="continuous")
        if wrap is not None:
            wrap(sched)
        reqs = workload()
        for r in reqs:
            sched.submit(r)
        t0 = time.perf_counter()
        done = sched.run()
        dt = time.perf_counter() - t0
        return sum(r.result.steps for r in done) / dt, dt, sched.steps

    for _ in range(6):                       # warm HLOs + engine caches
        drain()
    with obs.enabled(cfg=cfg, sals=sals, with_traffic=True):
        drain()                              # warm telemetry one-timers

    # -- disabled: measured guard cost × seam visits -----------------------
    probe = RequestScheduler(eng, mode="continuous")
    n_loop = 200_000
    t0 = time.perf_counter()
    for _ in range(n_loop):                  # the actual seam pattern
        if probe.tracer is not None:         # pragma: no cover
            raise AssertionError
    per_check_s = (time.perf_counter() - t0) / n_loop
    off = [drain() for _ in range(5)]
    off_tok = float(np.median([t for t, _, _ in off]))
    dis_pcts = [_OBS_SEAMS_PER_STEP * steps * per_check_s / wall * 100
                for _, wall, steps in off]
    dis_pct = float(np.median(dis_pcts))

    # -- enabled: in-drain attribution timing ------------------------------
    spent = {"t": 0.0}
    pc = time.perf_counter

    def timed(fn):
        def w(*a, **k):
            t0 = pc()
            try:
                return fn(*a, **k)
            finally:
                spent["t"] += pc() - t0
        return w

    def wrap(sched):
        tr, acct = sched.tracer, sched.traffic
        tr.begin = timed(tr.begin)
        tr.end = timed(tr.end)
        tr.end_track = timed(tr.end_track)
        tr.instant = timed(tr.instant)
        acct.observe_decode = timed(acct.observe_decode)
        acct.observe_transfer = timed(acct.observe_transfer)
        sched._publish_gauges = timed(sched._publish_gauges)

    en_pcts, on_toks = [], []
    for _ in range(5):
        with obs.enabled(cfg=cfg, sals=sals, with_traffic=True):
            spent["t"] = 0.0
            tok, wall, _ = drain(wrap=wrap)
            en_pcts.append(spent["t"] / wall * 100)
            on_toks.append(tok)
    en_pct = float(np.median(en_pcts))
    on_tok = float(np.median(on_toks))
    return [("obs-overhead-cpu", "disabled", round(off_tok, 1),
             round(dis_pct, 3), 1.0),
            ("obs-overhead-cpu", "enabled", round(on_tok, 1),
             round(en_pct, 2), 5.0)]


def run() -> list:
    rows = measured_rows() + projected_rows()
    common.emit(rows, ["table", "batch", "seq", "full_tok_s", "sals_tok_s",
                       "speedup"])
    print("# paper Table 7 reference: 1.4x @ 4k, 4.5x @ 32k vs GPT-fast")
    sched = scheduler_rows()
    common.emit(sched, ["table", "requests", "budget", "static_tok_s",
                        "continuous_tok_s", "speedup"])
    interleave = prefill_interleave_rows()
    common.emit(interleave, ["table", "mode", "config", "long_ttft_ms",
                             "max_intertoken_ms", "p99_intertoken_ms",
                             "median_intertoken_ms"])
    sharing = prefix_sharing_rows()
    common.emit(sharing, ["table", "mode", "requests", "last_ttft_ms",
                          "pages_high_water", "prefix_hits", "chunk_hlos",
                          "tok_s"])
    degradation = fault_degradation_rows()
    common.emit(degradation, ["table", "fault_rate", "done", "good_tok_s",
                              "p99_intertoken_ms", "retries", "step_faults",
                              "failures"])
    slo = slo_rows()
    common.emit(slo, ["table", "policy", "class", "ttft_ms",
                      "p99_gap_ms", "median_gap_ms", "good_tok_s", "parks",
                      "preemptions", "evictions"])
    spec = speculative_rows()
    common.emit(spec, ["table", "workload", "q_len", "acceptance",
                       "tok_per_round", "seq_tok_s", "spec_tok_s",
                       "speedup", "exact"])
    obs_rows = obs_overhead_rows()
    common.emit(obs_rows, ["table", "mode", "tok_s", "overhead_pct",
                           "budget_pct"])
    # read-modify-write: the modeled sections of BENCH_attention.json are
    # owned by benchmarks/attention_latency.py — only add the measured SLO
    # and speculative cells (drift-checked as required measured sections)
    import json
    from benchmarks.attention_latency import BENCH_JSON
    payload = json.loads(BENCH_JSON.read_text()) if BENCH_JSON.exists() \
        else {"bench": "attention"}
    payload["slo_report"] = [
        {"policy": p, "class": c, "ttft_ms": t, "p99_gap_ms": g,
         "median_gap_ms": m, "good_tok_s": tp, "parks": pk,
         "preemptions": pe, "evictions": ev}
        for _, p, c, t, g, m, tp, pk, pe, ev in slo]
    payload["speculative_throughput"] = [
        {"workload": w, "q_len": ql, "acceptance": a, "tok_per_round": tr,
         "seq_tok_s": sq, "spec_tok_s": sp, "speedup": x, "exact": ex}
        for _, w, ql, a, tr, sq, sp, x, ex in spec]
    payload["obs_overhead"] = [
        {"mode": m, "tok_s": t, "overhead_pct": o, "budget_pct": b}
        for _, m, t, o, b in obs_rows]
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote slo_report + speculative_throughput + obs_overhead -> "
          f"{BENCH_JSON}")
    return rows + sched + interleave + sharing + degradation + slo + spec \
        + obs_rows


if __name__ == "__main__":
    run()
