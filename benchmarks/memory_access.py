"""Paper Tables 2/3/4 — KV-cache memory-access ratios + accuracy proxy.

Memory-access ratio per decode step (paper §4.5): full attention moves
2·s·d_kv bf16 elements; SALS moves s·r* (scores) + N_sel·(r + v_bytes)
(+ the full-precision sink/recent windows).  We reproduce the paper's
reported ratios analytically from the SAME formula it uses, for the
paper's models (llama2-7b / mistral-7b geometry), and measure the accuracy
PROXY (next-token agreement + output MSE vs the uncompressed model) on a
model trained in this repo.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.config import SALSConfig
from repro.configs import get_config
from repro.core import latent_cache as lc
from benchmarks import common


def traffic_ratio(cfg, sals: SALSConfig, s: int) -> float:
    """SALS bytes moved / full-attention bytes moved per decode step."""
    kvd = cfg.kv_dim
    full = 2 * s * kvd * 2                              # K+V bf16
    r = sals.rank(kvd)
    r_star = sals.score_rank(kvd)
    n_sel = min(s, sals.n_critical)
    lat_bytes = 2 if sals.k_latent_dtype != "int8" else 1
    v_bytes = lc.cache_bytes_per_token(cfg, sals) - r * lat_bytes
    sals_traffic = (s * r_star * lat_bytes                 # scoring pass
                    + n_sel * (r * lat_bytes + v_bytes)    # gather+reconstruct
                    + (sals.n_sink + sals.n_recent) * 2 * kvd * 2)
    return sals_traffic / full


def storage_ratio(cfg, sals: SALSConfig) -> float:
    full = 2 * cfg.kv_dim * 2
    return lc.cache_bytes_per_token(cfg, sals) / full


def accuracy_proxy():
    """Next-token agreement + logit MSE of SALS vs full on a trained model."""
    cfg, params, corpus = common.trained_model()
    from repro.config import ServeConfig
    from repro.serve import ServeEngine
    out = {}
    full_engine = ServeEngine(params, None, cfg,
                              ServeConfig(max_seq_len=128, max_new_tokens=16,
                                          sals=SALSConfig(enabled=False)))
    prompts = [corpus.batch(9_000 + i, 1, 48)["tokens"][0] for i in range(8)]
    ref = full_engine.generate(prompts, max_new_tokens=16)
    for variant in ("25", "12.5"):
        sals = common.sals_settings(cfg, variant)
        proj = common.projectors_for(cfg, params, corpus, sals)
        eng = ServeEngine(params, proj, cfg,
                          ServeConfig(max_seq_len=128, max_new_tokens=16,
                                      sals=sals))
        got = eng.generate(prompts, max_new_tokens=16)
        agree = float(np.mean([np.mean(a.tokens == b.tokens)
                               for a, b in zip(ref, got)]))
        out[variant] = agree
    return out


def run() -> list:
    rows = []
    agree = accuracy_proxy()
    for model in ("paper-llama2-7b", "paper-mistral-7b", "yi-9b",
                  "gemma-2b"):
        cfg = get_config(model)
        s = 4096 if "llama2" in model else 32768
        for variant, label in (("25", "SALS-25%"), ("12.5", "SALS-12.5%")):
            sals = SALSConfig(
                rank_ratio=0.25 if variant == "25" else 0.125,
                v_bits=8 if variant == "25" else 4,
                n_critical=512 if s == 4096 else 1024,
                n_sink=16, n_recent=64 if s == 4096 else 128,
                v_group=min(64, cfg.kv_dim))
            rows.append((
                "table2/3", model, label, s,
                round(traffic_ratio(cfg, sals, s), 4),
                round(storage_ratio(cfg, sals), 4),
                round(agree.get(variant, float("nan")), 3),
            ))
    common.emit(rows, ["table", "model", "method", "seq", "memory_access",
                       "storage_ratio", "token_agreement_proxy"])
    # paper reference points (Table 3): SALS-25% -> 0.11, SALS-12.5% -> 0.06
    return rows


if __name__ == "__main__":
    run()
