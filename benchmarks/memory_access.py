"""Paper Tables 2/3/4 — KV-cache memory-access ratios + accuracy proxy.

Memory-access ratio per decode step (paper §4.5): full attention moves
2·s·d_kv bf16 elements; SALS moves s·r* (scores) + N_sel·(r + v_bytes)
(+ the full-precision sink/recent windows).  Under ragged continuous
batching every per-byte term is unchanged — row i simply pays its own
``s_i`` (its slot length) in place of the batch-wide ``s``, since the
kernels stream the same cache columns and only the per-row selectability
mask moves.  We reproduce the paper's
reported ratios analytically from the SAME formula it uses, for the
paper's models (llama2-7b / mistral-7b geometry), and measure the accuracy
PROXY (next-token agreement + output MSE vs the uncompressed model) on a
model trained in this repo.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.config import SALSConfig
from repro.configs import get_config
from repro.core import calibration as _cal
from repro.core import latent_cache as lc
from benchmarks import common


def traffic_ratio(cfg, sals: SALSConfig, s: int) -> float:
    """SALS bytes moved / full-attention bytes moved per decode step."""
    kvd = cfg.kv_dim
    full = 2 * s * kvd * 2                              # K+V bf16
    r = sals.rank(kvd)
    r_star = sals.score_rank(kvd)
    n_sel = min(s, sals.n_critical)
    lat_bytes = 2 if sals.k_latent_dtype != "int8" else 1
    v_bytes = lc.cache_bytes_per_token(cfg, sals) - r * lat_bytes
    sals_traffic = (s * r_star * lat_bytes                 # scoring pass
                    + n_sel * (r * lat_bytes + v_bytes)    # gather+reconstruct
                    + (sals.n_sink + sals.n_recent) * 2 * kvd * 2)
    return sals_traffic / full


def storage_ratio(cfg, sals: SALSConfig) -> float:
    full = 2 * cfg.kv_dim * 2
    return lc.cache_bytes_per_token(cfg, sals) / full


def decode_stage_bytes(cfg, sals: SALSConfig, s: int, fused: bool) -> dict:
    """Modeled HBM bytes/decode-step/layer, per pipeline stage.

    ``fused=False`` models the gather-then-attend path this repo shipped
    before ISSUE 1 (dense dequant pass for int8 scoring, ``[..., :r*]``
    slice + pad copies feeding the score kernel, XLA-gathered and
    dequantized (B, N_c, r)+(B, N_c, kvd) bf16 buffers feeding the
    attention kernel).  ``fused=True`` models the scalar-prefetch kernels:
    every §4.5 traffic term is paid exactly once, streaming from the raw
    quantized cache.  Each key maps a §4.5 term to the kernel that pays it
    (see ROADMAP "Decode dataflow & traffic model").
    """
    kvd = cfg.kv_dim
    r = sals.rank(kvd)
    r_star = sals.score_rank(kvd)
    nc = min(s, sals.n_critical)
    int8 = sals.k_latent_dtype == "int8"
    lat_b = 1 if int8 else 2
    scale_b = 2 if int8 else 0                    # bf16 per-token scale
    code_w = kvd // 2 if sals.v_bits == 4 else kvd
    v_meta = 2 * 2 * (kvd // sals.v_group)        # bf16 scale + zero
    v_tok = code_w + v_meta                       # stored value bytes/token
    from repro.kernels.latent_score import topk_candidate_shape
    attend_block = 256      # pre-PR-1 sparse_recon_attention DEFAULT_BLOCK_N

    def pad_to(n, m):
        return ((n + m - 1) // m) * m

    if fused:
        # scoring kernel streams the leading r* columns of the raw cache;
        # per-block candidates ((nb·kb) f32+i32 pairs) replace (B, S) scores
        nb, kb = topk_candidate_shape(s, sals.n_critical)
        score = s * (r_star * lat_b + scale_b) + 2 * nb * kb * 8
        # attention kernel DMAs each selected token's raw rows once
        selected = nc * (r * lat_b + scale_b + v_tok) + nc * 8   # + idx/valid
    else:
        # scoring: (int8 only) dense dequant pass, then slice copy, then a
        # pad copy when S isn't block-aligned, then the kernel read
        s_p = pad_to(s, min(1024, s))   # pre-PR-1 latent_score block size
        dequant = (s * (r + 2) + s * r * 2) if int8 else 0
        slice_copy = 2 * s * r_star * 2
        pad_copy = 2 * s_p * r_star * 2 if s_p != s else 0
        score = dequant + slice_copy + pad_copy + s_p * r_star * 2
        # selected: XLA gather reads raw rows, writes dense bf16 buffers,
        # kernel (after its own pad copy) reads them back
        nc_p = pad_to(nc, min(attend_block, nc))
        gather_read = nc * (r * lat_b + scale_b + v_tok)
        gather_write = nc * (r + kvd) * 2
        kernel_pad = 2 * nc_p * (r + kvd) * 2 if nc_p != nc else 0
        selected = gather_read + gather_write + kernel_pad \
            + nc_p * (r + kvd) * 2
    # identical on both paths: U_r (resident, stored bf16 with f32
    # in-kernel accumulate — see calibration.U_DTYPE), sink+recent window
    window = (sals.n_sink + sals.n_recent) * 2 * kvd * 2
    u_bytes = kvd * r * jnp.dtype(_cal.U_DTYPE).itemsize
    return {
        "score_bytes": score,
        "selected_bytes": selected,
        "window_bytes": window,
        "u_bytes": u_bytes,
        "total_bytes": score + selected + window + u_bytes,
    }


def prefill_chunk_bytes(cfg, sals: SALSConfig, chunk: int, s: int,
                        max_seq: int) -> dict:
    """Modeled HBM bytes for ONE chunked-prefill step per layer at chunk
    offset ``s`` (cache-so-far length), in a ``max_seq``-slot cache.

    The ONE-HLO design trades history-read bytes for zero recompiles: the
    chunk-vs-cache attend runs at a fixed shape, streaming the FULL
    (max_seq)-row K/V buffer every chunk with positions >= off merely
    masked — so ``*_streamed`` terms (what the current HLO actually moves)
    carry 2·max_seq·kvd regardless of ``s``, while ``*_live`` terms count
    only the useful 2·s·kvd history (what a length-bounded flash kernel
    would read; see the ROADMAP open item).  Both layers append the chunk
    (2·C·kvd write); SALS layers additionally pay the PROMPT-LIFETIME-ONLY
    full-precision scratch plus the incremental compressed writes: C latent
    rows, C quantized value rows, and the ring/sink inserts.  Activations
    are (B, C, d) per layer instead of the monolithic (B, S_prompt, d) —
    the chunk width, not the prompt length, bounds them.
    """
    from repro.core import quantization as qz
    kvd = cfg.kv_dim
    r = sals.rank(kvd)
    int8 = sals.k_latent_dtype == "int8"
    lat_b = 1 if int8 else 2
    scale_b = 2 if int8 else 0
    v_tok = qz.bytes_per_token(kvd, sals.v_bits, sals.v_group)  # code + meta
    hist_streamed = 2 * max_seq * kvd * 2        # fixed-shape HLO K+V read
    hist_live = 2 * s * kvd * 2                  # useful history bytes
    append = 2 * chunk * kvd * 2                 # chunk K/V append
    sals_writes = chunk * (r * lat_b + scale_b + v_tok) \
        + min(chunk, sals.n_recent + sals.n_sink) * 2 * kvd * 2
    return {
        "full_layer_bytes_streamed": hist_streamed + append,
        "full_layer_bytes_live": hist_live + append,
        "sals_layer_bytes_streamed": hist_streamed + append + sals_writes,
        "sals_layer_bytes_live": hist_live + append + sals_writes,
        "sals_compressed_write_bytes": sals_writes,
        "scratch_resident_bytes_per_token": 2 * kvd * 2,   # prefill-only
    }


def paged_capacity_model(cfg, sals: SALSConfig, page_size: int,
                         mean_live_tokens: int, max_seq: int,
                         n_requests: int = 8, shared_prefix: int = 0) -> dict:
    """ISSUE 5: HBM capacity + metadata model of the paged latent cache.

    The dense slot arena pins ``max_seq`` tokens of compressed cache per
    SLOT; the page pool pins ``ceil(live/ps)`` pages per SEQUENCE — the
    §4.5 traffic-model argument in reverse: SALS's cheap per-token bytes
    (``r·b_lat`` + quant metadata) make page-table metadata (one int32 per
    page = ``4/ps`` bytes/token) a rounding error, so paging is nearly
    free in overhead and the whole dense-vs-live gap converts to capacity.

    ``shared_prefix`` > 0 adds the prefix-sharing term: ``n_requests``
    same-prefix sequences store the prefix pages ONCE (plus per-sequence
    suffix pages) instead of ``n_requests`` full copies.

    ISSUE 7 refinement: sharing is not free — each retained
    ``PrefixEntry`` pins a RESUME SNAPSHOT beyond its pool pages (the
    registrant's dense single-request cache sized ``max_seq``, its
    prompt-lifetime full-precision K/V scratch, and one recent-ring
    snapshot per page boundary), so the honest sharing gain divides by
    ``shared + snapshot``, not ``shared`` alone.  The snapshot term is
    per retained ENTRY (one here), amortized across every request that
    resumes from it — the ledger shows both gains so the break-even
    (``n_requests`` large, prefix long) stays visible.
    """
    bpt = lc.cache_bytes_per_token(cfg, sals)            # compressed B/token
    table_overhead = 4.0 / page_size                     # int32 entry/page
    window_bytes = (sals.n_sink + sals.n_recent) * 2 * cfg.kv_dim * 2
    pages_live = -(-mean_live_tokens // page_size)
    dense_bytes = max_seq * bpt                          # per slot, pinned
    paged_bytes = pages_live * page_size * bpt \
        + (max_seq // page_size) * 4                     # pool + table row
    suffix = max(0, mean_live_tokens - shared_prefix)
    unshared_total = n_requests * pages_live * page_size * bpt
    shared_total = (-(-shared_prefix // page_size)
                    + n_requests * -(-suffix // page_size)) * page_size * bpt
    # resume snapshot pinned by one retained PrefixEntry (core/pager.py):
    # registrant's dense cache (max_seq slots) + windows, full-precision
    # prompt K/V scratch, and a recent-ring snapshot per page boundary
    prefix_pages = -(-shared_prefix // page_size)
    snapshot_bytes = (max_seq * bpt + window_bytes
                      + mean_live_tokens * 2 * cfg.kv_dim * 2
                      + prefix_pages * sals.n_recent * 2 * cfg.kv_dim * 2)
    shared_net = shared_total + snapshot_bytes
    return {
        "latent_bytes_per_token": round(bpt, 3),
        "page_table_bytes_per_token": round(table_overhead, 5),
        "page_overhead_fraction": round(table_overhead / bpt, 6),
        "window_bytes_per_resident": window_bytes,
        "dense_slot_bytes": dense_bytes,
        "paged_seq_bytes": paged_bytes,
        "capacity_gain": round(dense_bytes / paged_bytes, 2),
        "prefix_unshared_bytes": unshared_total,
        "prefix_shared_bytes": shared_total,
        "prefix_sharing_gain": round(unshared_total / max(shared_total, 1),
                                     2),
        "prefix_snapshot_bytes": snapshot_bytes,
        "prefix_sharing_gain_net": round(
            unshared_total / max(shared_net, 1), 2),
    }


def tiered_capacity_model(cfg, sals: SALSConfig, page_size: int,
                          live_pages: int, hbm_pages: int,
                          pages_touched: int,
                          cold_miss_rate: float) -> dict:
    """ISSUE 7: HBM / host / PCIe ledger of the two-tier page pool.

    SALS splits each page's bytes into a SCORE slice (leading ``r*``
    latent columns + per-token scale — read for EVERY live token by the
    selection pass, so it must stay HBM-resident for every live page)
    and a PAYLOAD (full-``r`` latent + quantized V — read only for the
    ``N_c`` selected tokens, so only ``hbm_pages`` device slots exist and
    the overflow lives in host mirrors).  Per decode step the PCIe/host
    link moves only demand-missed payloads::

        pcie_bytes_per_step = cold_miss_rate · pages_touched · ps
                              · (r·b_lat + b_scale + v_code + v_meta)

    where ``cold_miss_rate`` is the fraction of the step's touched pages
    that were cold (1 − selection stability × prefetch coverage — the
    measured step-to-step stability cell in ``benchmarks/overlap_score.py``
    is its empirical bound) and ``pages_touched`` the selection working
    set in pages.  HBM capacity stops scaling with live pages: the tiered
    device footprint is ``live·score + hbm_pages·payload`` against the
    single-tier ``live·(score-free) payload+latent`` — live-page capacity
    is bounded by host RAM.
    """
    kvd = cfg.kv_dim
    r_star = sals.score_rank(kvd)
    int8 = sals.k_latent_dtype == "int8"
    lat_b = 1 if int8 else 2
    scale_b = 2 if int8 else 0
    bpt = lc.cache_bytes_per_token(cfg, sals)
    score_bpt = r_star * lat_b + scale_b          # device-resident, per page
    # the score slice is a DUPLICATE of the leading r* latent columns (kept
    # so latent_topk never depends on residency), so the spillable payload
    # is the FULL stored per-token record, not ``bpt - score``
    payload_bpt = float(bpt)
    hbm_single = live_pages * page_size * bpt     # PR 5: everything hot
    hbm_tiered = (live_pages * page_size * score_bpt
                  + hbm_pages * page_size * payload_bpt
                  + live_pages * 8)               # page- + hot-table entries
    host_bytes = max(0, live_pages - hbm_pages) * page_size * payload_bpt
    pcie = cold_miss_rate * pages_touched * page_size * payload_bpt
    return {
        "page_size": page_size,
        "live_pages": live_pages,
        "hbm_pages": hbm_pages,
        "score_bytes_per_token": score_bpt,
        "payload_bytes_per_token": round(payload_bpt, 3),
        "hbm_bytes_single_tier": round(hbm_single),
        "hbm_bytes_tiered": round(hbm_tiered),
        "hbm_savings_x": round(hbm_single / hbm_tiered, 2),
        "host_mirror_bytes": round(host_bytes),
        "pages_touched_per_step": pages_touched,
        "cold_miss_rate": cold_miss_rate,
        "pcie_bytes_per_step": round(pcie, 1),
    }


def speculative_traffic_model(cfg, sals: SALSConfig, s: int, q_len: int,
                              acceptance: float) -> dict:
    """ISSUE 9: closed-form bytes/ACCEPTED-token of the speculative verify
    window vs sequential decode (no wall clock — drift-checkable).

    One verify window commits ``E[accepted] = 1 + acceptance·(q_len−1)``
    tokens: the pending token always commits (an all-rejected window still
    makes exactly sequential progress), and each of the ``q_len−1`` drafts
    commits iff every earlier draft did — with a per-draft acceptance rate
    ``acceptance`` the expected accepted-prefix length is bounded below by
    the linear term, which is also what the measured counters report
    (accepted drafts / proposed drafts).  Every §4.5 traffic term is paid
    once per WINDOW instead of once per TOKEN: the score stream (each live
    token's leading r* latent columns), the per-block candidate
    extraction, the selected-token gather+dequant+RoPE reconstruction
    (done ONCE, attending all q_len window queries), the resident U_r read
    and the full-precision sink/recent window.  The only extra bytes the
    window moves are its own in-flight K/V (``q_len·2·kvd`` bf16) — the
    simulated ring keeps draft tokens in registers, never in the cache.
    Dividing by E[accepted] gives the amortized per-token cost the ledger
    compares against the sequential ``decode_stage_bytes`` row.
    """
    seq = decode_stage_bytes(cfg, sals, s, fused=True)
    e_accept = 1.0 + acceptance * (q_len - 1)
    win_kv = q_len * 2 * cfg.kv_dim * 2           # window K/V, bf16
    spec_total = seq["total_bytes"] + win_kv
    return {
        "seq": s,
        "q_len": q_len,
        "acceptance": acceptance,
        "expected_accepted_per_window": round(e_accept, 3),
        "seq_score_bytes_per_token": round(seq["score_bytes"], 1),
        "spec_score_bytes_per_accepted": round(
            seq["score_bytes"] / e_accept, 1),
        "score_bytes_x": round(e_accept, 3),
        "window_kv_bytes": win_kv,
        "seq_total_bytes_per_token": round(seq["total_bytes"], 1),
        "spec_total_bytes_per_accepted": round(spec_total / e_accept, 1),
        "total_bytes_x": round(seq["total_bytes"] * e_accept / spec_total,
                               3),
    }


def fault_degradation_model(step_fault_rate: float, req_fault_rate: float,
                            mean_decode_steps: int,
                            max_retries: int = 2) -> dict:
    """ISSUE 6: closed-form graceful-degradation model of the fault-
    tolerant scheduler (no wall clock — drift-checkable).

    Two fault classes, matching the injection points:

    * STEP faults (``decode_step``) at per-step rate ``f``: the whole
      decode step retries and nobody pays tokens — committed-step
      throughput scales by ``1 - f`` (expected attempts per committed
      step is ``1/(1-f)``).
    * REQUEST faults (``page_alloc``/``admit``/``nan_logits``) at
      per-step rate ``q``: the victim request alone retries FROM SCRATCH
      (greedy re-run), up to ``max_retries`` times.  An attempt over
      ``T`` decode steps survives with ``p = (1-q)^T``; a failed attempt
      wastes on average ``~T/2`` steps (fault position is uniform over
      the attempt).  Goodput is committed tokens over total steps spent;
      the residual failure probability is ``(1-p)^(R+1)``.

    The measured counterpart (same rates, wall clock) is
    ``benchmarks/throughput.py::fault_degradation_rows``.
    """
    f, q, t, r = step_fault_rate, req_fault_rate, mean_decode_steps, \
        max_retries
    step_throughput = 1.0 - f
    p_attempt = (1.0 - q) ** t
    # truncated-geometric expected attempts started: Σ_{i=0..R} (1-p)^i
    attempts = sum((1.0 - p_attempt) ** i for i in range(r + 1))
    p_fail = (1.0 - p_attempt) ** (r + 1)
    # each attempt spends T steps if it survives, ~T/2 if it faults;
    # committed tokens only arrive when the request ultimately completes
    spent = attempts * (p_attempt * t + (1.0 - p_attempt) * t / 2.0)
    goodput = ((1.0 - p_fail) * t / spent) if spent else 1.0
    return {
        "step_fault_rate": f,
        "request_fault_rate": q,
        "mean_decode_steps": t,
        "max_retries": r,
        "step_throughput_x": round(step_throughput, 4),
        "request_attempts": round(attempts, 4),
        "request_fail_prob": round(p_fail, 6),
        "goodput_x": round(goodput * step_throughput, 4),
    }


def accuracy_proxy():
    """Next-token agreement + logit MSE of SALS vs full on a trained model."""
    cfg, params, corpus = common.trained_model()
    from repro.config import ServeConfig
    from repro.serve import ServeEngine
    out = {}
    full_engine = ServeEngine(params, None, cfg,
                              ServeConfig(max_seq_len=128, max_new_tokens=16,
                                          sals=SALSConfig(enabled=False)))
    prompts = [corpus.batch(9_000 + i, 1, 48)["tokens"][0] for i in range(8)]
    ref = full_engine.generate(prompts, max_new_tokens=16)
    for variant in ("25", "12.5"):
        sals = common.sals_settings(cfg, variant)
        proj = common.projectors_for(cfg, params, corpus, sals)
        eng = ServeEngine(params, proj, cfg,
                          ServeConfig(max_seq_len=128, max_new_tokens=16,
                                      sals=sals))
        got = eng.generate(prompts, max_new_tokens=16)
        agree = float(np.mean([np.mean(a.tokens == b.tokens)
                               for a, b in zip(ref, got)]))
        out[variant] = agree
    return out


def run() -> list:
    rows = []
    agree = accuracy_proxy()
    for model in ("paper-llama2-7b", "paper-mistral-7b", "yi-9b",
                  "gemma-2b"):
        cfg = get_config(model)
        s = 4096 if "llama2" in model else 32768
        for variant, label in (("25", "SALS-25%"), ("12.5", "SALS-12.5%")):
            sals = SALSConfig(
                rank_ratio=0.25 if variant == "25" else 0.125,
                v_bits=8 if variant == "25" else 4,
                n_critical=512 if s == 4096 else 1024,
                n_sink=16, n_recent=64 if s == 4096 else 128,
                v_group=min(64, cfg.kv_dim))
            rows.append((
                "table2/3", model, label, s,
                round(traffic_ratio(cfg, sals, s), 4),
                round(storage_ratio(cfg, sals), 4),
                round(agree.get(variant, float("nan")), 3),
            ))
    common.emit(rows, ["table", "model", "method", "seq", "memory_access",
                       "storage_ratio", "token_agreement_proxy"])
    # paper reference points (Table 3): SALS-25% -> 0.11, SALS-12.5% -> 0.06
    return rows


if __name__ == "__main__":
    run()
