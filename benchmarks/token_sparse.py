"""Paper Table 4 — token-sparse method comparison at EQUAL token budgets.

For each selection mechanism (SALS latent, Quest page-bounds,
Double-Sparsity outlier channels, oracle full-attention ranking), measure
on the repo-trained model:

  overlap — fraction of true attention mass captured by the selected set
  traffic — bytes moved per decode step (normalized to full attention)

Reproduces the paper's qualitative ordering: SALS matches/beats the sparse
heuristics on overlap while moving the least bytes (it reads compressed
latents; Quest/DS read full-precision K/V for the selected tokens).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import selection as sel
from repro.launch.serve import collect_pre_rope_keys
from repro.models import transformer as tf
from repro.models.attention import qkv_proj
from repro.models.layers import apply_rope, rmsnorm_apply
from benchmarks import common


def _attention_mass(q_r, k_r, keep, pos):
    """Head-mean softmax mass captured by ``keep`` (B, S)."""
    logits = jnp.einsum("bhd,bshd->bhs", q_r.astype(jnp.float32),
                        k_r.astype(jnp.float32)) / np.sqrt(q_r.shape[-1])
    s = k_r.shape[1]
    valid = (jnp.arange(s) <= pos)[None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).mean(axis=1)       # (B, S)
    return jnp.sum(jnp.where(keep, p, 0.0), axis=-1)


def run() -> list:
    cfg, params, corpus = common.trained_model(n_layers=4, steps=80)
    sals = common.sals_settings(cfg, "25")
    proj = common.projectors_for(cfg, params, corpus, sals)
    layer = 1
    pos, budget = 63, 16
    kvd = cfg.kv_dim

    # calibration keys for DS channels
    calib = np.asarray(collect_pre_rope_keys(
        params, cfg, {"tokens": jnp.asarray(
            corpus.batch(88_000, 4, 64)["tokens"])}))[layer].reshape(-1, kvd)
    ds_ch = jnp.asarray(bl.ds_label_channels(calib))

    scores_by_method = {m: [] for m in
                        ("sals", "quest", "ds", "oracle")}
    for i in range(6):
        toks = jnp.asarray(corpus.batch(90_000 + i, 2, pos + 1)["tokens"])
        keys = collect_pre_rope_keys(params, cfg, {"tokens": toks})
        x, _ = tf.embed_inputs(params, cfg, {"tokens": toks})
        for j in range(layer):
            bp = jax.tree.map(lambda a: a[j], params["blocks"])
            x, _, _ = tf._block_fwd(bp, x, cfg,
                                    jnp.arange(pos + 1)[None, :], 0, False)
        bp = jax.tree.map(lambda a: a[layer], params["blocks"])
        h = rmsnorm_apply(bp["attn_norm"], x, cfg.norm_eps)
        q, _, _ = qkv_proj(bp["attn"], h, cfg)
        q_last = q[:, -1]                                   # (B, H, dh)
        k_pre = keys[layer].reshape(2, pos + 1, cfg.n_kv_heads,
                                    cfg.head_dim)
        positions = jnp.arange(pos + 1)[None, :]
        q_r = apply_rope(q_last[:, None], jnp.full((2, 1), pos),
                         cfg.rope_theta)[:, 0]
        k_r = apply_rope(k_pre, positions, cfg.rope_theta)
        k_r_exp = jnp.repeat(k_r, cfg.group_size, axis=2)

        q_bar = sel.group_query(q_last, cfg)                # (B, kvd)
        k_flat = k_pre.reshape(2, pos + 1, kvd)
        k_flat_r = k_r.reshape(2, pos + 1, kvd)

        method_scores = {
            "sals": sel.latent_scores(
                q_bar, proj["u"][layer],
                k_flat.astype(jnp.float32) @ proj["u"][layer],
                sals.score_rank(kvd)),
            "quest": bl.quest_scores(
                sel.group_query(q_r, cfg), k_flat_r),
            "ds": bl.ds_scores(sel.group_query(q_r, cfg), k_flat_r, ds_ch),
            "oracle": jnp.einsum(
                "bhd,bshd->bs", q_r.astype(jnp.float32),
                k_r_exp.astype(jnp.float32)),
        }
        for m, sc in method_scores.items():
            mask = (jnp.arange(pos + 1) <= pos)[None, :]
            idx, valid = sel.topk_global(sc, jnp.broadcast_to(mask, sc.shape),
                                         budget)
            keep = jnp.zeros((2, pos + 1), bool)
            keep = jax.vmap(lambda kp, ix, vd: kp.at[ix].set(vd))(
                keep, idx, valid)
            ov = _attention_mass(q_r, k_r_exp, keep, pos)
            scores_by_method[m].append(np.asarray(ov))

    rows = []
    traffic = {
        "sals": bl.traffic_per_step("sals", cfg, pos + 1, budget, sals),
        "quest": bl.traffic_per_step("quest", cfg, pos + 1, budget),
        "ds": bl.traffic_per_step("ds", cfg, pos + 1, budget),
        "oracle": 1.0,
    }
    for m, vals in scores_by_method.items():
        rows.append(("table4", m, budget,
                     round(float(np.mean(vals)), 4),
                     round(traffic[m], 4)))
    common.emit(rows, ["table", "method", "token_budget", "overlap_score",
                       "memory_access"])
    sals_ov = float(np.mean(scores_by_method["sals"]))
    print(f"# paper Table 4: SALS highest accuracy at lowest memory access;"
          f" ours: SALS overlap {sals_ov:.3f} at "
          f"{traffic['sals']:.3f} traffic (budget {budget}/{pos + 1})")
    return rows


if __name__ == "__main__":
    run()
