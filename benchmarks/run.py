"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only mem,overlap,rank,...]
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = [
    ("mem", "Tables 2/3/4: memory access + accuracy proxy",
     "benchmarks.memory_access"),
    ("overlap", "Figure 2: overlap score across layers",
     "benchmarks.overlap_score"),
    ("rank", "Figure 4: key rank pre/post RoPE",
     "benchmarks.rank_analysis"),
    ("sparse", "Table 4: token-sparse method comparison",
     "benchmarks.token_sparse"),
    ("attn", "Table 6: attention operator latency",
     "benchmarks.attention_latency"),
    ("tput", "Table 7: end-to-end decode throughput",
     "benchmarks.throughput"),
    ("ruler", "Table 5 proxy: retrieval recall of latent selection",
     "benchmarks.ruler_proxy"),
    ("roofline", "§Roofline: dry-run roofline table",
     "benchmarks.roofline_report"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list of section keys to run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []
    for key, title, module in SECTIONS:
        if only and key not in only:
            continue
        print(f"\n{'=' * 72}\n== [{key}] {title}\n{'=' * 72}")
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"== [{key}] done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append(key)
    if failures:
        print(f"\nFAILED sections: {failures}")
        return 1
    print("\nAll benchmark sections completed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
