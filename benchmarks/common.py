"""Shared benchmark plumbing: a trained small model + calibrated projectors
(cached across benchmarks), timing helpers, CSV emit."""
from __future__ import annotations

import functools
import time
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SALSConfig, TrainConfig
from repro.configs import get_config
from repro.data import SyntheticCorpus, make_batches
from repro.launch.serve import calibrate, collect_pre_rope_keys
from repro.train import trainer


@functools.lru_cache(maxsize=2)
def trained_model(arch: str = "qwen2-1.5b", steps: int = 60,
                  vocab: int = 512, n_layers: int = 3):
    """Train a reduced model on the synthetic corpus (accuracy proxies run
    against THIS model — no pretrained 7B weights ship offline)."""
    cfg = get_config(arch).reduced(n_layers=n_layers, vocab_size=vocab)
    tcfg = TrainConfig(steps=steps, batch_size=8, seq_len=64, lr=5e-3,
                       warmup_steps=5, log_every=1_000_000)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    state = trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg, jnp.float32)
    step = jax.jit(trainer.make_train_step(cfg, tcfg))
    for i, batch in zip(range(tcfg.steps), make_batches(corpus, 8, 64)):
        state, _ = step(state, jax.tree.map(jnp.asarray, batch))
    return cfg, state["params"], corpus


def sals_settings(cfg, variant: str) -> SALSConfig:
    """Paper §5: SALS-25% and SALS-12.5% (scaled to the reduced model)."""
    rr = 0.25 if variant == "25" else 0.125
    return SALSConfig(rank_ratio=rr, score_ratio=0.5,
                      v_bits=8 if variant == "25" else 4,
                      n_critical=16, n_sink=2, n_recent=8,
                      v_group=min(32, cfg.kv_dim),
                      skip_layers_front=1, skip_layers_back=1)


def projectors_for(cfg, params, corpus, sals):
    return calibrate(params, cfg, sals, corpus, n_sequences=16, seq_len=64)


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3
            ) -> Tuple[float, float]:
    """(mean_us, std_us) per call; blocks on the first output leaf."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.mean(ts)), float(np.std(ts))


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
