"""Paper Table 6 — attention-operator latency: SALS decode attention vs
full-cache (FlashAttention-role) decode attention.

CPU wall-clock on REDUCED shapes (this container's measurement) plus the
v5e roofline-model projection for the paper's shapes (bs 8/16 × 1k..32k)
from the §4.5 traffic formula — the projection is what the dry-run's perf
story uses; the CPU timing demonstrates the operator actually runs and that
the SALS/full ratio moves in the predicted direction.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HBM_BW
from repro.config import SALSConfig
from repro.configs import get_config
from repro.core import calibration as cal
from repro.core import latent_cache as lc
from repro.core.sparse_attention import sals_decode_attend
from repro.models import attention as attn
from repro.models import transformer as tf
from benchmarks import common
from benchmarks.memory_access import (decode_stage_bytes,
                                      fault_degradation_model,
                                      paged_capacity_model,
                                      prefill_chunk_bytes,
                                      speculative_traffic_model,
                                      tiered_capacity_model, traffic_ratio)

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_attention.json"


def measured_rows():
    """CPU wall-clock of one layer's decode attention, full vs SALS."""
    cfg = get_config("qwen2-1.5b").reduced(n_layers=1)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg, jnp.float32)
    bp = jax.tree.map(lambda a: a[0], params["blocks"])
    rows = []
    for bs, s in [(4, 1024), (4, 2048), (8, 1024)]:
        x = jax.random.normal(key, (bs, 1, cfg.d_model), jnp.float32)
        # full-cache decode attention
        kc = jax.random.normal(key, (bs, s, cfg.n_kv_heads, cfg.head_dim),
                               jnp.float32)
        vc = jnp.roll(kc, 1, axis=1)

        @jax.jit
        def full(x, kc, vc):
            return attn.attend_decode_full(bp["attn"], x, cfg, kc, vc,
                                           jnp.int32(s - 1))[0]

        t_full, sd_full = common.time_fn(full, x, kc, vc, iters=10)

        sals = SALSConfig(rank_ratio=0.25, n_critical=min(432, s // 4),
                          n_sink=16, n_recent=64, v_group=32)
        proj = cal.random_layer_projectors(key, cfg, sals, 1)
        u = proj["u"][0]
        cache = lc.LatentKVCache.init(cfg, sals, 1, bs, s, jnp.float32)
        layer = cache.layer_view(0)

        @jax.jit
        def sparse(x, layer):
            y, _ = sals_decode_attend(bp["attn"], u, layer, x,
                                      jnp.int32(s - 1), cfg, sals)
            return y

        t_sals, sd_sals = common.time_fn(sparse, x, layer, iters=10)
        rows.append(("table6-cpu", bs, s, round(t_full, 1), round(t_sals, 1),
                     round(t_full / t_sals, 2)))
    return rows


def projected_rows():
    """v5e HBM-roofline projection at the paper's shapes (memory-bound
    operator: latency ≈ bytes_moved / HBM_bw)."""
    cfg = get_config("paper-llama2-7b")
    rows = []
    for bs in (8, 16):
        for s in (1024, 2048, 4096, 32768):
            full_bytes = bs * 2 * s * cfg.kv_dim * 2 * cfg.n_layers
            t_full = full_bytes / HBM_BW * 1e6
            for variant in ("25", "12.5"):
                sals = SALSConfig(
                    rank_ratio=0.25 if variant == "25" else 0.125,
                    v_bits=8 if variant == "25" else 4,
                    n_critical=512 if s <= 4096 else 1024,
                    n_sink=16, n_recent=64, v_group=64)
                ratio = traffic_ratio(cfg, sals, s)
                rows.append((f"table6-v5e-SALS{variant}", bs, s,
                             round(t_full, 1), round(t_full * ratio, 1),
                             round(1 / ratio, 2)))
    return rows


def traffic_model_rows():
    """ISSUE 1 ledger: modeled HBM bytes/step/layer for the old
    (gather-then-attend) vs new (fused scalar-prefetch gather) decode
    paths, per stage, at 4k/32k/128k."""
    cfg = get_config("paper-llama2-7b")
    rows = []
    for s in (4096, 32768, 131072):
        for kdt in ("bfloat16", "int8"):
            sals = SALSConfig(rank_ratio=0.25, v_bits=8,
                              n_critical=512 if s <= 4096 else 1024,
                              n_sink=16, n_recent=64, v_group=64,
                              k_latent_dtype=kdt)
            old = decode_stage_bytes(cfg, sals, s, fused=False)
            new = decode_stage_bytes(cfg, sals, s, fused=True)
            rows.append({
                "model": "paper-llama2-7b", "seq": s, "k_latent_dtype": kdt,
                "old": old, "new": new,
                "score_ratio": round(old["score_bytes"] / new["score_bytes"], 2),
                "selected_ratio": round(
                    old["selected_bytes"] / new["selected_bytes"], 2),
                "total_ratio": round(old["total_bytes"] / new["total_bytes"], 2),
            })
    return rows


def prefill_traffic_rows():
    """ISSUE 4 ledger: modeled HBM bytes of ONE chunked-prefill step per
    layer (full vs SALS layers, incl. the prompt-lifetime scratch term) at
    representative chunk offsets — both the fixed-shape-HLO streamed bytes
    and the live (length-bounded-kernel) bytes."""
    cfg = get_config("paper-llama2-7b")
    sals = SALSConfig(rank_ratio=0.25, v_bits=8, n_critical=512,
                      n_sink=16, n_recent=64, v_group=64)
    max_seq = 32768
    rows = []
    for chunk in (256, 512):
        for s in (0, 4096, 32768):
            m = prefill_chunk_bytes(cfg, sals, chunk, s, max_seq)
            rows.append({"model": "paper-llama2-7b", "chunk": chunk,
                         "cache_so_far": s, "max_seq": max_seq, **m})
    return rows


def paged_capacity_rows():
    """ISSUE 5 ledger: paged-pool capacity + metadata model at the paper
    config — per-token page-table overhead (< 2% of latent bytes by
    orders of magnitude), dense-slot vs live-page residency, and the
    prefix-sharing storage term (§4.5 traffic-model capacity argument)."""
    cfg = get_config("paper-llama2-7b")
    rows = []
    for variant, v_bits, ratio in (("25", 8, 0.25), ("12.5", 4, 0.125)):
        sals = SALSConfig(rank_ratio=ratio, v_bits=v_bits, n_critical=512,
                          n_sink=16, n_recent=64, v_group=64)
        for page_size in (16, 64, 256):
            m = paged_capacity_model(cfg, sals, page_size,
                                     mean_live_tokens=512, max_seq=4096,
                                     n_requests=8, shared_prefix=256)
            rows.append({"model": "paper-llama2-7b",
                         "sals": f"SALS-{variant}%",
                         "page_size": page_size, "mean_live_tokens": 512,
                         "max_seq": 4096, **m})
    return rows


def tiered_capacity_rows():
    """ISSUE 7 ledger: two-tier page pool at the paper config — HBM bytes
    single-tier vs tiered (score slices for every live page + ``hbm_pages``
    payload slots), host-mirror footprint, and the PCIe bytes/step the
    selection working set demands at representative cold-miss rates (the
    measured step-to-step selection-stability cell bounds the miss rate:
    a stable selection prefetches itself)."""
    cfg = get_config("paper-llama2-7b")
    rows = []
    for variant, v_bits, ratio in (("25", 8, 0.25), ("12.5", 4, 0.125)):
        sals = SALSConfig(rank_ratio=ratio, v_bits=v_bits, n_critical=512,
                          n_sink=16, n_recent=64, v_group=64)
        # 8 residents × 4k live tokens at page 64 = 512 live pages; the
        # per-step working set is the sorted whole-page burst bound
        # n_critical/ps per row
        page_size = 64
        live = 8 * 4096 // page_size
        touched = 8 * (sals.n_critical // page_size)
        for hbm_pages in (live // 4, live // 2):
            for miss in (0.02, 0.10):
                m = tiered_capacity_model(cfg, sals, page_size,
                                          live_pages=live,
                                          hbm_pages=hbm_pages,
                                          pages_touched=touched,
                                          cold_miss_rate=miss)
                rows.append({"model": "paper-llama2-7b",
                             "sals": f"SALS-{variant}%", **m})
    return rows


def fault_degradation_rows():
    """ISSUE 6 ledger: modeled graceful degradation of the fault-tolerant
    scheduler — committed-step throughput, expected per-request attempts,
    residual failure probability, and goodput at the chaos-suite fault
    rates.  The measured counterpart (same rates, wall clock on the tiny
    CPU model) lives in ``benchmarks/throughput.py``."""
    rows = []
    for f, q in ((0.0, 0.0), (0.01, 0.0), (0.05, 0.0),
                 (0.0, 0.001), (0.0, 0.005), (0.01, 0.001), (0.05, 0.005)):
        for t in (64, 256):
            rows.append({"scheduler": "continuous",
                         **fault_degradation_model(f, q, t, max_retries=2)})
    return rows


def speculative_traffic_rows():
    """ISSUE 9 ledger: modeled score-stream bytes per ACCEPTED token under
    speculative verify windows vs the sequential fused decode row.  One
    latent selection + one reconstruction serves the whole q_len window, so
    every cache-traffic term divides by E[accepted] = 1 + α·(q_len−1); the
    acceptance sweep brackets the measured drafter (repetitive prompts sit
    near α≈1, novel text near α≈0.25)."""
    cfg = get_config("paper-llama2-7b")
    rows = []
    for s in (4096, 32768):
        sals = SALSConfig(rank_ratio=0.25, v_bits=8,
                          n_critical=512 if s <= 4096 else 1024,
                          n_sink=16, n_recent=64, v_group=64)
        for q_len in (2, 4, 8):
            for acceptance in (0.25, 0.5, 0.75):
                rows.append({"model": "paper-llama2-7b",
                             **speculative_traffic_model(
                                 cfg, sals, s, q_len, acceptance)})
    return rows


def run() -> list:
    cpu_rows = measured_rows()
    v5e_rows = projected_rows()
    rows = cpu_rows + v5e_rows
    common.emit(rows, ["table", "batch", "seq", "full_us", "sals_us",
                       "speedup"])
    print("# paper Table 6 reference: 5.7x attention speedup at bs=8, 4k")
    model_rows = traffic_model_rows()
    common.emit(
        [(r["seq"], r["k_latent_dtype"],
          r["old"]["score_bytes"], r["new"]["score_bytes"], r["score_ratio"],
          r["old"]["selected_bytes"], r["new"]["selected_bytes"],
          r["selected_ratio"], r["total_ratio"]) for r in model_rows],
        ["seq", "k_lat", "score_old_B", "score_new_B", "score_x",
         "sel_old_B", "sel_new_B", "sel_x", "total_x"])
    prefill_rows = prefill_traffic_rows()
    common.emit(
        [(r["chunk"], r["cache_so_far"], r["full_layer_bytes_streamed"],
          r["full_layer_bytes_live"], r["sals_layer_bytes_streamed"],
          r["sals_compressed_write_bytes"]) for r in prefill_rows],
        ["chunk", "cache_so_far", "full_streamed_B", "full_live_B",
         "sals_streamed_B", "sals_write_B"])
    paged_rows = paged_capacity_rows()
    common.emit(
        [(r["sals"], r["page_size"], r["latent_bytes_per_token"],
          r["page_overhead_fraction"], r["capacity_gain"],
          r["prefix_sharing_gain"]) for r in paged_rows],
        ["sals", "page", "lat_B_tok", "table_frac", "capacity_x",
         "prefix_x"])
    tiered_rows = tiered_capacity_rows()
    common.emit(
        [(r["sals"], r["hbm_pages"], r["live_pages"],
          r["hbm_savings_x"], r["host_mirror_bytes"],
          r["cold_miss_rate"], r["pcie_bytes_per_step"])
         for r in tiered_rows],
        ["sals", "hbm_pages", "live_pages", "hbm_x", "host_B",
         "miss_rate", "pcie_B_step"])
    fault_rows = fault_degradation_rows()
    common.emit(
        [(r["step_fault_rate"], r["request_fault_rate"],
          r["mean_decode_steps"], r["step_throughput_x"],
          r["request_attempts"], r["request_fail_prob"], r["goodput_x"])
         for r in fault_rows],
        ["step_f", "req_f", "steps", "step_x", "attempts", "p_fail",
         "goodput_x"])
    spec_rows = speculative_traffic_rows()
    common.emit(
        [(r["seq"], r["q_len"], r["acceptance"],
          r["expected_accepted_per_window"],
          r["seq_score_bytes_per_token"],
          r["spec_score_bytes_per_accepted"], r["score_bytes_x"],
          r["total_bytes_x"]) for r in spec_rows],
        ["seq", "q_len", "accept", "E_acc", "score_seq_B",
         "score_spec_B", "score_x", "total_x"])
    cols = ["table", "batch", "seq", "full_us", "sals_us", "speedup"]
    payload = {
        "bench": "attention",
        "unit": "modeled HBM bytes/decode-step/layer (+ measured CPU us)",
        "measured_cpu": [dict(zip(cols, r)) for r in cpu_rows],
        "projected_v5e": [dict(zip(cols, r)) for r in v5e_rows],
        "traffic_model": model_rows,
        "prefill_traffic_model": prefill_rows,
        "paged_capacity_model": paged_rows,
        "tiered_capacity_model": tiered_rows,
        "fault_degradation_model": fault_rows,
        "speculative_traffic_model": spec_rows,
    }
    # measured cells emitted by other benchmarks (overlap_score writes
    # selection_stability, throughput writes slo_report,
    # speculative_throughput and obs_overhead) live in the same file —
    # carry them across re-emits
    if BENCH_JSON.exists():
        prev = json.loads(BENCH_JSON.read_text())
        for section in ("selection_stability", "slo_report",
                        "speculative_throughput", "obs_overhead"):
            if section in prev:
                payload[section] = prev[section]
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {BENCH_JSON}")
    return rows + model_rows


if __name__ == "__main__":
    run()
